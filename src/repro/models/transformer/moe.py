"""Mixture-of-Experts FFN: top-k routing with capacity, two dispatch
implementations.

  * ``einsum``  - GShard-style one-hot dispatch/combine tensors
                  (arXiv:2006.16668).  SPMD-friendly (all-to-alls fall out of
                  sharded einsums) but pays O(g * E * C * d) dispatch FLOPs.
  * ``scatter`` - position-computed scatter/gather dispatch: FLOP-minimal
                  (O(T * d) data movement, no dispatch matmuls).  This is the
                  beyond-paper optimization lever measured in EXPERIMENTS.md
                  §Perf.

Tokens are processed in groups of ``group_size`` along the (data-sharded)
leading axis, so per-group capacity C = ceil(g * top_k * cf / E) bounds both
memory and imbalance; overflow tokens are dropped (standard GShard
semantics) and pass through the residual connection only.

Expert networks are SwiGLU MLPs (mixtral / granite style); expert weights
are stacked (E, ...) so they shard over the model axis as expert parallelism
when E divides the axis, falling back to tensor parallelism on d_ff.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 512
    impl: str = "einsum"              # einsum | scatter
    router_mode: str = "topk_softmax"  # softmax over the selected logits
    # reassociate the combine so the tensor-parallel psum of the expert
    # output happens on the (g, d) token domain instead of the (E, C, d)
    # slot domain — E*C/g ~ 2.5x fewer bytes on the wire, and the psum
    # operand stays in the compute dtype (bf16) instead of the f32
    # accumulator (see EXPERIMENTS.md §Perf / mixtral prefill).
    fused_combine: bool = False


def capacity(cfg: MoEConfig) -> int:
    c = int(np.ceil(cfg.group_size * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(c, 1)


import numpy as np  # noqa: E402  (after dataclass to keep header tight)


def router_probs(logits: jax.Array, cfg: MoEConfig):
    """Top-k selection.  Returns (gates (..., k), experts (..., k) int32).

    ``topk_softmax`` (mixtral/granite): softmax over the k selected logits.
    """
    gates_logits, experts = jax.lax.top_k(logits, cfg.top_k)
    if cfg.router_mode == "topk_softmax":
        gates = jax.nn.softmax(gates_logits.astype(jnp.float32), axis=-1)
    else:  # softmax_topk: softmax over all experts, then select
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        gates = jnp.take_along_axis(probs, experts, axis=-1)
    return gates.astype(logits.dtype), experts


def _expert_ffn(w_gate, w_in, w_out, x):
    """SwiGLU expert: x (E, C, d), weights (E, d, f)/(E, f, d)."""
    g = jnp.einsum("ecd,edf->ecf", x, w_gate)
    h = jnp.einsum("ecd,edf->ecf", x, w_in)
    a = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", a, w_out)


def _positions_in_expert(experts: jax.Array, gates: jax.Array, cfg: MoEConfig):
    """Flatten (g, k) choices; compute each choice's slot within its expert.

    Priority is (token, choice) order — earlier tokens keep their slots when
    capacity overflows (GShard).  Returns flat (g*k,) expert ids, slot ids,
    gate values, and keep mask.
    """
    g = experts.shape[0]
    flat_e = experts.reshape(g * cfg.top_k)
    flat_gate = gates.reshape(g * cfg.top_k)
    onehot = jax.nn.one_hot(flat_e, cfg.n_experts, dtype=jnp.int32)  # (gk, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot                       # 1-based
    slot = (pos.sum(axis=-1) - 1).astype(jnp.int32)                 # (gk,)
    keep = slot < capacity(cfg)
    return flat_e, slot, flat_gate, keep


def moe_ffn_group(x: jax.Array, router_w: jax.Array, w_gate, w_in, w_out,
                  cfg: MoEConfig) -> jax.Array:
    """One group: x (g, d) -> (g, d)."""
    gsz, d = x.shape
    C = capacity(cfg)
    logits = x @ router_w                                  # (g, E)
    gates, experts = router_probs(logits, cfg)             # (g, k)

    flat_e, slot, flat_gate, keep = _positions_in_expert(experts, gates, cfg)

    if cfg.impl == "einsum":
        # dispatch: (g, E, C) combine weights; bf16 keeps the tensor small.
        oh_e = jax.nn.one_hot(flat_e, cfg.n_experts, dtype=x.dtype)
        oh_c = jax.nn.one_hot(slot, C, dtype=x.dtype) * keep[:, None].astype(x.dtype)
        disp = (oh_e[:, :, None] * oh_c[:, None, :]).reshape(gsz, cfg.top_k, cfg.n_experts, C).sum(1)
        comb = (oh_e[:, :, None] * oh_c[:, None, :] * flat_gate[:, None, None]
                ).reshape(gsz, cfg.top_k, cfg.n_experts, C).sum(1)
        ex_in = jnp.einsum("gec,gd->ecd", disp, x)
        if cfg.fused_combine:
            # combine BEFORE the w_out contraction: the partial sums that
            # the partitioner must all-reduce live on (g, d) not (E, C, d).
            g_ = jnp.einsum("ecd,edf->ecf", ex_in, w_gate)
            h_ = jnp.einsum("ecd,edf->ecf", ex_in, w_in)
            a = (jax.nn.silu(g_) * h_).astype(x.dtype)
            z = jnp.einsum("gec,ecf->egf", comb, a)      # per-expert tokens
            return jnp.einsum("egf,efd->gd", z, w_out)   # contract f AND e
        ex_out = _expert_ffn(w_gate, w_in, w_out, ex_in)
        return jnp.einsum("gec,ecd->gd", comb, ex_out)

    # scatter impl — FLOP-minimal data movement
    tok_idx = jnp.repeat(jnp.arange(gsz), cfg.top_k)
    safe_slot = jnp.where(keep, slot, 0)
    ex_in = jnp.zeros((cfg.n_experts, C, d), x.dtype)
    ex_in = ex_in.at[flat_e, safe_slot].add(
        jnp.where(keep[:, None], x[tok_idx], 0.0)
    )
    ex_out = _expert_ffn(w_gate, w_in, w_out, ex_in)
    gathered = ex_out[flat_e, safe_slot]                    # (gk, d)
    contrib = gathered * (flat_gate * keep.astype(flat_gate.dtype))[:, None]
    return jax.ops.segment_sum(contrib, tok_idx, num_segments=gsz)


def moe_ffn(x: jax.Array, router_w: jax.Array, w_gate, w_in, w_out,
            cfg: MoEConfig) -> jax.Array:
    """x: (..., d) — flattens leading dims into groups of cfg.group_size."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    T = flat.shape[0]
    gsz = min(cfg.group_size, T)
    assert T % gsz == 0, (T, gsz)
    grouped = flat.reshape(T // gsz, gsz, d)
    out = jax.vmap(
        lambda xs: moe_ffn_group(xs, router_w, w_gate, w_in, w_out,
                                 dataclasses.replace(cfg, group_size=gsz))
    )(grouped)
    return out.reshape(*lead, d)


def load_balancing_loss(logits: jax.Array, experts: jax.Array, cfg: MoEConfig):
    """Switch-style aux loss: E * sum_e f_e * p_e (arXiv:2101.03961)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p_mean = probs.reshape(-1, cfg.n_experts).mean(0)
    counts = jax.nn.one_hot(experts.reshape(-1), cfg.n_experts).mean(0) * cfg.top_k
    return cfg.n_experts * jnp.sum(p_mean * counts)
