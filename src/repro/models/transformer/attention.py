"""Attention for the LM architectures: GQA + RoPE, with three execution
strategies sharing one numerics definition:

  * ``dense``    - materializes (B, H, Sq, Skv) scores.  Only for short
                   sequences / smoke tests.
  * ``chunked``  - lax.scan over query chunks; peak score memory is
                   (B, H, q_chunk, Skv).  This is the dry-run/compile path —
                   no S x S tensor ever exists at 32k/500k.
  * windowed     - chunked + a static sliding window W: each query chunk
                   attends to a dynamic_slice of W + q_chunk keys, so FLOPs
                   scale as O(S * W) instead of O(S^2)  (mixtral SWA,
                   gemma3 local layers).
  * decode       - single-position queries against a (possibly
                   sequence-sharded) KV cache; softmax reductions over the
                   sharded key axis become psums under SPMD (flash-decoding
                   split-K, expressed at the XLA level).

The Pallas flash kernel (repro.kernels.flash_attention) implements the same
contract for real TPU runs and is validated against these in interpret mode.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) * 2.0 / hd))
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)           # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Core masked attention on explicit position indices (GQA layout).
#   q: (B, Sq, K, G, hd)   k/v: (B, Skv, K, hd)
# ---------------------------------------------------------------------------

def _attend(q, k, v, q_pos, k_pos, window, softmax_scale):
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    logits = logits * softmax_scale
    causal = k_pos[..., None, None, None, :] <= q_pos[..., None, None, :, None]
    mask = causal
    if window is not None:
        mask = mask & (
            k_pos[..., None, None, None, :]
            > q_pos[..., None, None, :, None] - window
        )
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def gqa_attention(
    q: jax.Array,           # (B, Sq, H, hd)
    k: jax.Array,           # (B, Skv, KV, hd)
    v: jax.Array,           # (B, Skv, KV, hd)
    *,
    n_kv_heads: int,
    q_positions: jax.Array,   # (B, Sq) or (Sq,)
    k_positions: jax.Array,   # (B, Skv) or (Skv,)
    window: int | None = None,
    q_chunk: int | None = None,
) -> jax.Array:
    """Causal (optionally sliding-window) GQA.  Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    G = H // n_kv_heads
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Sq, n_kv_heads, G, hd)
    q_pos = jnp.broadcast_to(q_positions, (B, Sq))
    k_pos = jnp.broadcast_to(k_positions, (B, Skv))

    if q_chunk is None or Sq <= q_chunk:
        out = _attend(qg, k, v, q_pos, k_pos, window, scale)
        return out.reshape(B, Sq, H, hd)

    if Sq % q_chunk != 0:
        # pad queries to a chunk multiple; padded rows are sliced away.
        pad = q_chunk - Sq % q_chunk
        qg_p = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        qp_p = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=0)
        out = gqa_attention(
            qg_p.reshape(B, Sq + pad, H, hd), k, v,
            n_kv_heads=n_kv_heads, q_positions=qp_p, k_positions=k_pos,
            window=window, q_chunk=q_chunk)
        return out[:, :Sq]
    n_chunks = Sq // q_chunk
    qs = qg.reshape(B, n_chunks, q_chunk, n_kv_heads, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(B, n_chunks, q_chunk).transpose(1, 0, 2)

    use_window_slice = window is not None and (window + q_chunk) < Skv
    if use_window_slice:
        # keys needed by chunk i: positions (i*qc - W, i*qc + qc - 1]
        span = window + q_chunk

        def body(carry, xs):
            qc_i, qp_i, i = xs
            start = jnp.clip(i * q_chunk + q_chunk - span, 0, Skv - span)
            k_i = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kp_i = jax.lax.dynamic_slice_in_dim(k_pos, start, span, axis=1)
            o = _attend(qc_i, k_i, v_i, qp_i, kp_i, window, scale)
            return carry, o
    else:

        def body(carry, xs):
            qc_i, qp_i, i = xs
            o = _attend(qc_i, k, v, qp_i, k_pos, window, scale)
            return carry, o

    idx = jnp.arange(n_chunks)
    # nested remat: without it, scan saves every chunk's f32 score matrix as
    # a bwd residual — an (n_chunks, B, KV, G, qc, Skv) stack that dwarfs the
    # model.  With it, bwd recomputes one chunk's scores at a time.
    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qs, qp, idx))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return out


def decode_attention(
    q: jax.Array,            # (B, 1, H, hd) — one new position per sequence
    k_cache: jax.Array,      # (B, S, KV, hd)
    v_cache: jax.Array,
    *,
    n_kv_heads: int,
    cache_index: jax.Array,  # () current position (0-based) of the new token
    window: int | None = None,
) -> jax.Array:
    """One-step decode against a full cache (new k/v already written)."""
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    k_pos = jnp.arange(S)
    q_pos = jnp.full((B, 1), cache_index)
    return gqa_attention(
        q, k_cache, v_cache,
        n_kv_heads=n_kv_heads,
        q_positions=q_pos,
        k_positions=k_pos,
        window=window,
        q_chunk=None,
    )
