"""The paper's model family: FM / FwFM / pruned-FwFM / DPLR-FwFM.

    phi(x) = b0 + <b, x> + pairwise(V)                    (Sections 3-4)

with ``pairwise`` selected by ``cfg.interaction``:
    "fm"     - Rendle's O(mk) identity
    "fwfm"   - full O(m^2 k) field-weighted interactions (Eq. 3)
    "dplr"   - the paper's O(rho m k) reformulation (Prop. 1) [contribution]
Pruned FwFM is not a training-time variant: per the paper's protocol a
trained "fwfm" model is magnitude-pruned post hoc (``repro.core.pruning``)
and served through the pruned ranking path.

Two serving entry points:
  * ``apply``       - pointwise scoring of full rows (training / eval)
  * ``rank_items``  - Algorithm 1: one context, n candidate items, with the
                      context computation cached (the latency-critical path)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ranking as rk
from repro.core.dplr import DPLRParams, init_dplr
from repro.core.fields import FeatureLayout
from repro.core.interactions import (
    dplr_pairwise,
    fm_pairwise,
    fwfm_pairwise,
    pruned_pairwise_dense,
)
from repro.embedding.bag import (
    init_embedding_table,
    item_arena_ids,
    lookup_field_embeddings,
    lookup_item_embeddings,
    lookup_linear_terms,
    padded_rows,
)


@dataclasses.dataclass(frozen=True)
class FwFMConfig:
    layout: FeatureLayout
    embed_dim: int = 8
    interaction: str = "dplr"        # fm | fwfm | dplr
    rank: int = 3                    # DPLR rank rho
    task: str = "ctr"                # ctr (logloss) | rating (mse)
    dtype: Any = jnp.float32
    # Route the dplr rank_items hot loop through the Pallas kernel
    # (kernels.ops.dplr_score_items: Mosaic on TPU, interpret on CPU).
    use_pallas_kernels: bool = False

    @property
    def n_fields(self) -> int:
        return self.layout.n_fields


def init(rng: jax.Array, cfg: FwFMConfig) -> dict:
    k_emb, k_lin, k_int = jax.random.split(rng, 3)
    rows = padded_rows(cfg.layout.total_vocab)
    params = {
        "bias": jnp.zeros((), cfg.dtype),
        "linear": jnp.zeros((rows,), cfg.dtype),
        "embedding": init_embedding_table(
            k_emb, rows, cfg.embed_dim, dtype=cfg.dtype
        ),
    }
    m = cfg.n_fields
    if cfg.interaction == "fwfm":
        # symmetric, zero-diagonal; store full matrix, symmetrize in apply.
        params["R"] = (jax.random.normal(k_int, (m, m)) * 0.1).astype(cfg.dtype)
    elif cfg.interaction == "dplr":
        u, e = init_dplr(k_int, m, cfg.rank, dtype=cfg.dtype)
        params["U"], params["e"] = u, e
    elif cfg.interaction != "fm":
        raise ValueError(cfg.interaction)
    return params


def field_matrix(params: dict, cfg: FwFMConfig) -> jax.Array:
    """Symmetric zero-diagonal R from the raw parameter (fwfm only)."""
    Rp = params["R"]
    R = 0.5 * (Rp + Rp.T)
    return R - jnp.diag(jnp.diag(R))


def _pairwise(params: dict, cfg: FwFMConfig, V: jax.Array,
              pruned_mask: jax.Array | None) -> jax.Array:
    if cfg.interaction == "fm":
        return fm_pairwise(V)
    if cfg.interaction == "fwfm":
        R = field_matrix(params, cfg)
        if pruned_mask is not None:
            return pruned_pairwise_dense(V, R, pruned_mask)
        return fwfm_pairwise(V, R)
    return dplr_pairwise(V, DPLRParams(params["U"], params["e"]))


def apply(params: dict, cfg: FwFMConfig, batch: dict,
          pruned_mask: jax.Array | None = None, take_fn=None) -> jax.Array:
    """Pointwise logits/scores for full rows: batch = {ids, weights}."""
    ids, w = batch["ids"], batch["weights"]
    V = lookup_field_embeddings(params["embedding"], cfg.layout, ids, w,
                                take_fn=take_fn)
    lin = lookup_linear_terms(params["linear"], cfg.layout, ids, w,
                              take_fn=take_fn)
    return params["bias"] + lin + _pairwise(params, cfg, V, pruned_mask)


def loss(params: dict, cfg: FwFMConfig, batch: dict, take_fn=None) -> jax.Array:
    logits = apply(params, cfg, batch, take_fn=take_fn)
    y = batch["label"].astype(logits.dtype)
    if cfg.task == "ctr":
        # numerically-stable binary cross-entropy on logits
        per = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    else:
        per = (logits - y) ** 2
    return per.mean()


# ---------------------------------------------------------------------------
# Ranking (Algorithm 1 and cached baselines)
# ---------------------------------------------------------------------------

def _check_context_first(layout: FeatureLayout) -> None:
    kinds = [f.kind for f in layout.fields]
    nC = layout.n_context
    if kinds != ["context"] * nC + ["item"] * (len(kinds) - nC):
        raise ValueError("rank_items requires context fields before item fields")


def context_inputs(params: dict, cfg: FwFMConfig, ctx_ids: jax.Array,
                   ctx_w: jax.Array, take_fn=None) -> tuple[jax.Array, jax.Array]:
    """(V_C, lin_C): the context-side lookups shared by ``rank_items`` and
    the corpus serving engine (one definition of the per-query step 0)."""
    ctx_layout = cfg.layout.subset("context")
    V_C = lookup_field_embeddings(params["embedding"], ctx_layout, ctx_ids,
                                  ctx_w, take_fn=take_fn)
    lin_C = lookup_linear_terms(params["linear"], ctx_layout, ctx_ids,
                                ctx_w, take_fn=take_fn)
    return V_C, lin_C


def rank_items(params: dict, cfg: FwFMConfig, query: dict,
               pruned: Any = None, take_fn=None) -> jax.Array:
    """Score n items for each query context.  Shapes:

        query = {
          "context_ids":     (Bq, n_ctx_slots),
          "context_weights": (Bq, n_ctx_slots),
          "item_ids":        (Bq, n, n_item_slots),
          "item_weights":    (Bq, n, n_item_slots),
        }

    Returns (Bq, n) scores.  The context-only work is O(1) per query,
    independent of n — the paper's Algorithm 1.  ``pruned`` is an optional
    ``repro.core.pruning.PrunedR`` for serving a pruned fwfm model.
    """
    layout = cfg.layout
    _check_context_first(layout)
    item_layout = layout.subset("item")
    table = params["embedding"]
    lin = params["linear"]

    # context side (cached per query) + item side; lin_C/lin_I are the
    # first-order terms, context part cached, item part per item.
    V_C, lin_C = context_inputs(params, cfg, query["context_ids"],
                                query["context_weights"], take_fn=take_fn)
    V_I = lookup_item_embeddings(table, layout, query["item_ids"],
                                 query["item_weights"], take_fn=take_fn)
    lin_I = lookup_linear_terms(lin, item_layout,
                                item_arena_ids(layout, query["item_ids"]),
                                query["item_weights"], take_fn=take_fn)
    first_order = params["bias"] + lin_C[..., None] + lin_I

    nC = layout.n_context
    if cfg.interaction == "fm":
        cache = rk.fm_context_cache(V_C)
        pw = rk.fm_score_items(cache, V_I)
    elif cfg.interaction == "dplr":
        p = DPLRParams(params["U"], params["e"])
        cache = rk.dplr_context_cache(p, V_C, nC)
        if cfg.use_pallas_kernels:
            from repro.core.dplr import dplr_diagonal
            from repro.kernels import ops as kops
            d = dplr_diagonal(p)
            pw = jax.vmap(
                lambda v, pc, sc: kops.dplr_score_items(
                    v, p.U[:, nC:], p.e, d[nC:], pc, sc)
            )(V_I, cache.P_C, cache.s_C)
        else:
            pw = rk.dplr_score_items(p, cache, V_I, nC)
    elif pruned is not None:
        groups = rk.split_pruned_entries(pruned.entries_i, pruned.entries_j,
                                         pruned.entries_r, nC)
        cache = rk.pruned_context_cache(groups, V_C, layout.n_item)
        pw = rk.pruned_score_items(groups, cache, V_I)
    else:
        R = field_matrix(params, cfg)
        cache = rk.fwfm_context_cache(R, V_C, nC)
        pw = rk.fwfm_score_items(R, cache, V_I, nC)
    return first_order + pw


# ---------------------------------------------------------------------------
# Model-parallel DPLR scoring (beyond-paper optimization, EXPERIMENTS.md
# §Perf): the paper's Proposition-1 projection is LINEAR in the field
# embeddings, so it distributes over the sharded-arena partial sums:
#
#     P = U V = U (sum_shards V_s) = sum_shards (U V_s)
#
# Each model shard projects its locally-owned embedding rows to the rank-rho
# subspace BEFORE the cross-shard reduction, so the psum moves
# (rho*k + 2) floats per item instead of (m_item*k + m_item + ...) —
# a (m k)/(rho k) ~ 12x collective-byte reduction for the paper's deployed
# geometry — and the projection FLOPs spread across the model axis.
# The quadratic d-term stays exact because every one-hot field's embedding
# row lives on exactly one shard (sum ||v_i||^2 = sum_shards ||v_i^s||^2).
# ---------------------------------------------------------------------------

def rank_items_mp(params: dict, cfg: FwFMConfig, query: dict, *,
                  mesh, item_spec, model_axis: str = "model") -> jax.Array:
    """Distributed Algorithm 1 for ``interaction == 'dplr'`` models.

    ``item_spec``: PartitionSpec of the (Bq, n, slots) item ids (batch-dim
    sharding over the DP axes).  Requires a one-hot layout (multiplicity 1
    for every field).
    """
    from jax.sharding import PartitionSpec as P

    assert cfg.interaction == "dplr"
    layout = cfg.layout
    _check_context_first(layout)
    assert all(f.multiplicity == 1 for f in layout.fields), \
        "model-parallel d-term requires one-hot fields"
    nC = layout.n_context
    mI = layout.n_item
    k = cfg.embed_dim
    rho = cfg.rank

    ctx_offsets = jnp.asarray(layout.field_offsets[:nC])
    item_offsets = jnp.asarray(layout.field_offsets[nC:])

    def body(table, lin, U, e, bias, ctx_ids, ctx_w, item_ids, item_w):
        shard = jax.lax.axis_index(model_axis)
        rows_per = table.shape[0]
        d = -jnp.einsum("r,rm,rm->m", e, U, U)

        def local_rows(ids):
            owner = ids // rows_per
            local = ids - owner * rows_per
            mine = owner == shard
            rows = jnp.take(table, jnp.where(mine, local, 0), axis=0)
            lin_v = jnp.take(lin, jnp.where(mine, local, 0), axis=0)
            rows = jnp.where(mine[..., None], rows, 0.0)
            lin_v = jnp.where(mine, lin_v, 0.0)
            return rows, lin_v

        # context side (once per query); the 0.5 of Eq. (5) is folded into
        # the d-term partials so the psum'd scalars are final addends.
        # weights cast to the table dtype — a stray f32 here promotes every
        # downstream partial (and its psum) to f32.
        ctx_w = ctx_w.astype(table.dtype)
        item_w = item_w.astype(table.dtype)
        U = U.astype(table.dtype)
        e = e.astype(table.dtype)
        vC, linC = local_rows(ctx_ids + ctx_offsets)         # (Bq, nC, k)
        vC = vC * ctx_w[..., None]
        P_C_part = jnp.einsum("rm,qmk->qrk", U[:, :nC], vC)
        s_C_part = (0.5 * jnp.einsum("qmk,m->q", vC * vC, d[:nC])
                    + (linC * ctx_w).sum(-1))

        # item side (per candidate)
        vI, linI = local_rows(item_ids + item_offsets)       # (Bq, n, mI, k)
        vI = vI * item_w[..., None]
        P_I_part = jnp.einsum("rm,qnmk->qnrk", U[:, nC:], vI)
        s_I_part = (0.5 * jnp.einsum("qnmk,m->qn", vI * vI, d[nC:])
                    + (linI * item_w).sum(-1))

        # the ONLY cross-shard traffic: rank-rho projections + scalars
        P_C = jax.lax.psum(P_C_part, model_axis)             # (Bq, rho, k)
        s_C = jax.lax.psum(s_C_part, model_axis)             # (Bq,)
        P_I = jax.lax.psum(P_I_part, model_axis)             # (Bq, n, rho, k)
        s_I = jax.lax.psum(s_I_part, model_axis)             # (Bq, n)

        Pfull = P_C[:, None] + P_I
        term_e = 0.5 * jnp.einsum("qnrk,r->qn", Pfull * Pfull, e)
        return bias + s_C[:, None] + s_I + term_e

    from repro.sharding import shard_map

    qspec = P(*item_spec[:-1])    # scores follow the item batch dims
    lin2d = params["linear"]
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(model_axis, None), P(model_axis), P(), P(), P(),
                  P(None, None), P(None, None), item_spec, item_spec),
        out_specs=qspec,
    )(params["embedding"], lin2d, params["U"], params["e"], params["bias"],
      query["context_ids"], query["context_weights"],
      query["item_ids"], query["item_weights"])
