"""Behavior Sequence Transformer (Chen et al. 2019, arXiv:1905.06874).

Assigned config: embed_dim=32, seq_len=20, n_blocks=1, n_heads=8,
MLP 1024-512-256.  The user's behavior history (item ids) plus the target
item form a (seq_len+1)-token sequence; learned positional embeddings are
added; ``n_blocks`` post-LN transformer blocks run over it; the flattened
sequence states are concatenated with the "other features" (context field
embeddings) and fed to the MLP head.

Layout convention: all context fields first, then exactly ONE item field —
the item id vocabulary shared between history tokens and the target item.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.fields import FeatureLayout
from repro.embedding.bag import (init_embedding_table, lookup_field_embeddings,
                                padded_rows)
from repro.models.layers import (
    apply_layer_norm,
    apply_mha,
    apply_mlp,
    init_layer_norm,
    init_mha,
    init_mlp,
)


@dataclasses.dataclass(frozen=True)
class BSTConfig:
    layout: FeatureLayout          # context fields + 1 item field
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    ffn_mult: int = 4
    mlp_dims: tuple[int, ...] = (1024, 512, 256)
    dtype: Any = jnp.float32

    @property
    def n_tokens(self) -> int:
        return self.seq_len + 1   # history + target item


def init(rng: jax.Array, cfg: BSTConfig) -> dict:
    d = cfg.embed_dim
    keys = jax.random.split(rng, 4 + 4 * cfg.n_blocks)
    blocks = {}
    for i in range(cfg.n_blocks):
        k0, k1 = keys[4 + 4 * i], keys[5 + 4 * i]
        blocks[f"block_{i}"] = {
            "ln1": init_layer_norm(d, cfg.dtype),
            "mha": init_mha(k0, d, d // cfg.n_heads, cfg.n_heads, dtype=cfg.dtype),
            "ln2": init_layer_norm(d, cfg.dtype),
            "ffn": init_mlp(k1, [d, cfg.ffn_mult * d, d], cfg.dtype),
        }
    n_ctx = cfg.layout.n_context
    mlp_in = cfg.n_tokens * d + n_ctx * d
    return {
        "embedding": init_embedding_table(keys[0], padded_rows(cfg.layout.total_vocab),
                                          d, dtype=cfg.dtype),
        "pos": (jax.random.normal(keys[1], (cfg.n_tokens, d)) * 0.02).astype(cfg.dtype),
        "head": init_mlp(keys[2], [mlp_in, *cfg.mlp_dims, 1], cfg.dtype),
        **blocks,
    }


def _item_arena_offset(cfg: BSTConfig) -> int:
    return int(cfg.layout.field_offsets[cfg.layout.n_context])


def _encode_sequence(params: dict, cfg: BSTConfig, hist_ids, hist_mask, target_ids,
                     take_fn=None):
    """(batch..., L) history + (batch...,) target -> (batch..., L+1, d)."""
    table = params["embedding"]
    take = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    off = _item_arena_offset(cfg)
    hist_e = take(table, hist_ids + off)
    tgt_e = take(table, target_ids + off)
    seq = jnp.concatenate([hist_e, tgt_e[..., None, :]], axis=-2) + params["pos"]
    mask1d = jnp.concatenate(
        [hist_mask, jnp.ones((*hist_mask.shape[:-1], 1), hist_mask.dtype)], axis=-1
    )
    attn_mask = mask1d[..., None, :] * mask1d[..., :, None]
    h = seq
    for i in range(cfg.n_blocks):
        blk = params[f"block_{i}"]
        a = apply_mha(blk["mha"], h, n_heads=cfg.n_heads, mask=attn_mask)
        h = apply_layer_norm(blk["ln1"], h + a)
        f = apply_mlp(blk["ffn"], h, activation=jax.nn.leaky_relu)
        h = apply_layer_norm(blk["ln2"], h + f)
    return h * mask1d[..., None]


def apply(params: dict, cfg: BSTConfig, batch: dict, take_fn=None) -> jax.Array:
    """batch: ids/weights (context+item slots), hist_ids, hist_mask."""
    layout = cfg.layout
    V = lookup_field_embeddings(params["embedding"], layout, batch["ids"],
                                batch["weights"], take_fn=take_fn)
    n_ctx = layout.n_context
    target_ids = batch["ids"][..., layout.n_slots - 1]   # single item slot (last)
    h = _encode_sequence(params, cfg, batch["hist_ids"], batch["hist_mask"],
                         target_ids, take_fn=take_fn)
    feats = jnp.concatenate(
        [h.reshape(*h.shape[:-2], -1), V[..., :n_ctx, :].reshape(*V.shape[:-2], -1)],
        axis=-1,
    )
    return apply_mlp(params["head"], feats, activation=jax.nn.leaky_relu)[..., 0]


def loss(params: dict, cfg: BSTConfig, batch: dict, take_fn=None) -> jax.Array:
    logits = apply(params, cfg, batch, take_fn=take_fn)
    y = batch["label"].astype(logits.dtype)
    per = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return per.mean()


def rank_items(params: dict, cfg: BSTConfig, query: dict,
               take_fn=None) -> jax.Array:
    """Score n candidate items: the target item sits INSIDE the transformer
    sequence, so the whole encoder re-runs per candidate (cost profile:
    O(n * L^2 d) — the expensive end of the serving spectrum).

    query: context_ids/context_weights, hist_ids (Bq, L), hist_mask,
           item_ids (Bq, n, 1).
    """
    layout = cfg.layout
    ctx_layout = layout.subset("context")
    V_C = lookup_field_embeddings(params["embedding"], ctx_layout,
                                  query["context_ids"], query["context_weights"],
                                  take_fn=take_fn)
    n = query["item_ids"].shape[-2]
    hist_ids = jnp.broadcast_to(query["hist_ids"][..., None, :],
                                (*query["hist_ids"].shape[:-1], n, cfg.seq_len))
    hist_mask = jnp.broadcast_to(query["hist_mask"][..., None, :], hist_ids.shape)
    h = _encode_sequence(params, cfg, hist_ids, hist_mask,
                         query["item_ids"][..., 0], take_fn=take_fn)
    ctx_flat = V_C.reshape(*V_C.shape[:-2], -1)
    ctx_flat = jnp.broadcast_to(ctx_flat[..., None, :], (*h.shape[:-3], n, ctx_flat.shape[-1]))
    feats = jnp.concatenate([h.reshape(*h.shape[:-2], -1), ctx_flat], axis=-1)
    return apply_mlp(params["head"], feats, activation=jax.nn.leaky_relu)[..., 0]
