"""AutoInt (Song et al. 2019, arXiv:1810.11921).

Assigned config: n_sparse=39, embed_dim=16, 3 interacting (self-attention)
layers, 2 heads, d_attn=32.  Each interacting layer applies multi-head
self-attention over the m field embeddings with a residual projection and
ReLU; the final field states are concatenated and mapped to a logit, plus a
global first-order term.

Note the structural parallel the paper draws: AutoInt's field self-attention
is O(m^2 (d_attn + k)) per example — the same quadratic-in-fields cost class
as full FwFM.  ``use_dplr_head`` optionally adds the paper's O(rho m k)
DPLR-FwFM branch.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.dplr import DPLRParams, init_dplr
from repro.core.fields import FeatureLayout
from repro.core.interactions import dplr_pairwise
from repro.embedding.bag import (
    init_embedding_table,
    lookup_field_embeddings,
    lookup_linear_terms,
    padded_rows,
)
from repro.models.layers import glorot, init_mha, apply_mha


@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    layout: FeatureLayout
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32          # total attention width (per paper's config)
    use_dplr_head: bool = False
    dplr_rank: int = 3
    dtype: Any = jnp.float32


def init(rng: jax.Array, cfg: AutoIntConfig) -> dict:
    keys = jax.random.split(rng, cfg.n_attn_layers * 2 + 3)
    d_head = cfg.d_attn // cfg.n_heads
    d = cfg.embed_dim
    layers = {}
    for i in range(cfg.n_attn_layers):
        d_in = d if i == 0 else cfg.d_attn
        layers[f"attn_{i}"] = init_mha(keys[2 * i], d_in, d_head, cfg.n_heads,
                                       d_out=cfg.d_attn, dtype=cfg.dtype)
        layers[f"res_{i}"] = glorot(keys[2 * i + 1], (d_in, cfg.d_attn), cfg.dtype)
    rows = padded_rows(cfg.layout.total_vocab)
    params = {
        "bias": jnp.zeros((), cfg.dtype),
        "linear": jnp.zeros((rows,), cfg.dtype),
        "embedding": init_embedding_table(keys[-3], rows, d,
                                          dtype=cfg.dtype),
        "out_w": glorot(keys[-2], (cfg.layout.n_fields * cfg.d_attn, 1), cfg.dtype),
        **layers,
    }
    if cfg.use_dplr_head:
        u, e = init_dplr(keys[-1], cfg.layout.n_fields, cfg.dplr_rank, dtype=cfg.dtype)
        params["U"], params["e"] = u, e
    return params


def _interact(params: dict, cfg: AutoIntConfig, V: jax.Array) -> jax.Array:
    h = V
    for i in range(cfg.n_attn_layers):
        attn = apply_mha(params[f"attn_{i}"], h, n_heads=cfg.n_heads, scaled=False)
        h = jax.nn.relu(attn + h @ params[f"res_{i}"])
    return h


def apply(params: dict, cfg: AutoIntConfig, batch: dict, take_fn=None) -> jax.Array:
    ids, w = batch["ids"], batch["weights"]
    V = lookup_field_embeddings(params["embedding"], cfg.layout, ids, w,
                                take_fn=take_fn)
    h = _interact(params, cfg, V)
    logit = (h.reshape(*h.shape[:-2], -1) @ params["out_w"])[..., 0]
    lin = lookup_linear_terms(params["linear"], cfg.layout, ids, w,
                              take_fn=take_fn)
    out = params["bias"] + lin + logit
    if cfg.use_dplr_head:
        out = out + dplr_pairwise(V, DPLRParams(params["U"], params["e"]))
    return out


def loss(params: dict, cfg: AutoIntConfig, batch: dict, take_fn=None) -> jax.Array:
    logits = apply(params, cfg, batch, take_fn=take_fn)
    y = batch["label"].astype(logits.dtype)
    per = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return per.mean()


def rank_items(params: dict, cfg: AutoIntConfig, query: dict,
               take_fn=None) -> jax.Array:
    """Candidate scoring.  Field self-attention must see the joint
    (context + item) field set, so — unlike DPLR-FwFM — the full O(m^2)
    interaction re-runs per candidate; only the embedding gathers of the
    context side are shared.  This is the cost profile the paper's technique
    removes for FwFM-class models."""
    layout = cfg.layout
    ctx_layout = layout.subset("context")
    item_layout = layout.subset("item")
    ctx_vocab = ctx_layout.total_vocab
    from repro.embedding.bag import embedding_bag
    V_C = lookup_field_embeddings(params["embedding"], ctx_layout,
                                  query["context_ids"], query["context_weights"],
                                  take_fn=take_fn)
    item_rows = query["item_ids"] + ctx_vocab + jnp.asarray(item_layout.slot_offsets)
    V_I = embedding_bag(params["embedding"], item_rows, query["item_weights"],
                        item_layout.slot_to_field, item_layout.n_fields,
                        take_fn=take_fn)
    V_Cb = jnp.broadcast_to(V_C[..., None, :, :],
                            (*V_I.shape[:-2], ctx_layout.n_fields, cfg.embed_dim))
    V = jnp.concatenate([V_Cb, V_I], axis=-2)
    h = _interact(params, cfg, V)
    logit = (h.reshape(*h.shape[:-2], -1) @ params["out_w"])[..., 0]
    lin_c = lookup_linear_terms(params["linear"], ctx_layout,
                                query["context_ids"], query["context_weights"],
                                take_fn=take_fn)
    take = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    lin_i = (take(params["linear"].reshape(-1, 1), item_rows)[..., 0]
             * query["item_weights"]).sum(-1)
    out = params["bias"] + lin_c[..., None] + lin_i + logit
    if cfg.use_dplr_head:
        out = out + dplr_pairwise(V, DPLRParams(params["U"], params["e"]))
    return out
