"""Wide & Deep (Cheng et al. 2016, arXiv:1606.07792).

Assigned config: n_sparse=40 fields, embed_dim=32, MLP 1024-512-256,
interaction = concat.  Wide part: first-order weights over all sparse
features (the cross-product transforms of the original paper are a data-side
feature-engineering step; first-order over the hashed crosses is the
standard open-source formulation).  Deep part: concat of field embeddings
-> ReLU MLP -> logit.  Output: wide + deep (joint training).

Beyond-paper option (``use_dplr_head``): adds a DPLR-FwFM pairwise branch —
the paper-under-reproduction's technique as a composable head, giving
Wide&Deep second-order field interactions at O(rho m k) serving cost.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.dplr import DPLRParams, init_dplr
from repro.core.fields import FeatureLayout
from repro.core.interactions import dplr_pairwise
from repro.embedding.bag import (
    init_embedding_table,
    lookup_field_embeddings,
    lookup_linear_terms,
    padded_rows,
)
from repro.models.layers import apply_mlp, init_mlp


@dataclasses.dataclass(frozen=True)
class WideDeepConfig:
    layout: FeatureLayout
    embed_dim: int = 32
    mlp_dims: tuple[int, ...] = (1024, 512, 256)
    use_dplr_head: bool = False
    dplr_rank: int = 3
    dtype: Any = jnp.float32


def init(rng: jax.Array, cfg: WideDeepConfig) -> dict:
    k_emb, k_mlp, k_dplr = jax.random.split(rng, 3)
    d_in = cfg.layout.n_fields * cfg.embed_dim
    rows = padded_rows(cfg.layout.total_vocab)
    params = {
        "bias": jnp.zeros((), cfg.dtype),
        "wide": jnp.zeros((rows,), cfg.dtype),
        "embedding": init_embedding_table(
            k_emb, rows, cfg.embed_dim, dtype=cfg.dtype
        ),
        "deep": init_mlp(k_mlp, [d_in, *cfg.mlp_dims, 1], cfg.dtype),
    }
    if cfg.use_dplr_head:
        u, e = init_dplr(k_dplr, cfg.layout.n_fields, cfg.dplr_rank, dtype=cfg.dtype)
        params["U"], params["e"] = u, e
    return params


def apply(params: dict, cfg: WideDeepConfig, batch: dict, take_fn=None) -> jax.Array:
    ids, w = batch["ids"], batch["weights"]
    V = lookup_field_embeddings(params["embedding"], cfg.layout, ids, w,
                                take_fn=take_fn)
    wide = lookup_linear_terms(params["wide"], cfg.layout, ids, w,
                               take_fn=take_fn)
    deep_in = V.reshape(*V.shape[:-2], -1)
    deep = apply_mlp(params["deep"], deep_in)[..., 0]
    out = params["bias"] + wide + deep
    if cfg.use_dplr_head:
        out = out + dplr_pairwise(V, DPLRParams(params["U"], params["e"]))
    return out


def loss(params: dict, cfg: WideDeepConfig, batch: dict, take_fn=None) -> jax.Array:
    logits = apply(params, cfg, batch, take_fn=take_fn)
    y = batch["label"].astype(logits.dtype)
    per = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return per.mean()


def rank_items(params: dict, cfg: WideDeepConfig, query: dict,
               take_fn=None) -> jax.Array:
    """Candidate scoring: context embeddings gathered once, MLP per item.

    Unlike the FwFM family there is no factorization that removes the
    per-item MLP cost — the concat interaction forces a full deep pass per
    candidate.  (This is exactly the serving-cost contrast the paper draws.)
    """
    layout = cfg.layout
    ctx_layout = layout.subset("context")
    item_layout = layout.subset("item")
    ctx_vocab = ctx_layout.total_vocab

    from repro.embedding.bag import embedding_bag
    V_C = lookup_field_embeddings(params["embedding"], ctx_layout,
                                  query["context_ids"], query["context_weights"],
                                  take_fn=take_fn)
    item_rows = query["item_ids"] + ctx_vocab + jnp.asarray(item_layout.slot_offsets)
    V_I = embedding_bag(params["embedding"], item_rows, query["item_weights"],
                        item_layout.slot_to_field, item_layout.n_fields,
                        take_fn=take_fn)

    n = V_I.shape[-3]
    V_Cb = jnp.broadcast_to(V_C[..., None, :, :], (*V_I.shape[:-2], ctx_layout.n_fields, cfg.embed_dim))
    V = jnp.concatenate([V_Cb, V_I], axis=-2)

    wide_c = lookup_linear_terms(params["wide"], ctx_layout,
                                 query["context_ids"], query["context_weights"],
                                 take_fn=take_fn)
    take = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    wide_i = (take(params["wide"].reshape(-1, 1), item_rows)[..., 0]
              * query["item_weights"]).sum(-1)
    deep = apply_mlp(params["deep"], V.reshape(*V.shape[:-2], -1))[..., 0]
    out = params["bias"] + wide_c[..., None] + wide_i + deep
    if cfg.use_dplr_head:
        out = out + dplr_pairwise(V, DPLRParams(params["U"], params["e"]))
    return out
