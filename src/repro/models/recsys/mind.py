"""MIND: Multi-Interest Network with Dynamic routing (Li et al. 2019,
arXiv:1904.08030).

Assigned config: embed_dim=64, n_interests=4, capsule_iters=3.

Pipeline: user behavior history (item ids) -> behavior capsules ->
Behavior-to-Interest (B2I) dynamic routing with a shared bilinear map S ->
``n_interests`` interest capsules (squash nonlinearity) -> label-aware
attention (softmax over pow-p scaled interest-target dots) for training.
Retrieval serving scores a candidate as max_k <interest_k, e_item> — i.e.
the per-candidate cost is O(K k), already "item-only" in the paper's sense.

Routing logits are randomly initialized and NOT learned (per the paper:
fixed random init breaks interest symmetry); we sample them at ``init`` and
stop gradients through them.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.fields import FeatureLayout
from repro.embedding.bag import init_embedding_table, padded_rows
from repro.models.layers import glorot


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    layout: FeatureLayout          # context fields + 1 item field (shared vocab)
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    label_pow: float = 2.0         # p in label-aware attention
    n_neg: int = 8                 # sampled-softmax negatives (training)
    dtype: Any = jnp.float32


def init(rng: jax.Array, cfg: MINDConfig) -> dict:
    k_emb, k_s, k_b = jax.random.split(rng, 3)
    d = cfg.embed_dim
    return {
        "embedding": init_embedding_table(k_emb, padded_rows(cfg.layout.total_vocab),
                                          d, dtype=cfg.dtype),
        "S": glorot(k_s, (d, d), cfg.dtype),               # shared bilinear map
        "b_init": (jax.random.normal(k_b, (cfg.n_interests, cfg.seq_len))).astype(cfg.dtype),
    }


def _item_arena_offset(cfg: MINDConfig) -> int:
    return int(cfg.layout.field_offsets[cfg.layout.n_context])


def _squash(v: jax.Array) -> jax.Array:
    n2 = (v * v).sum(axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * v * jax.lax.rsqrt(n2 + 1e-9)


def user_interests(params: dict, cfg: MINDConfig, hist_ids: jax.Array,
                   hist_mask: jax.Array, take_fn=None) -> jax.Array:
    """(batch..., L) -> (batch..., K, d) interest capsules via B2I routing."""
    off = _item_arena_offset(cfg)
    take = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    e = take(params["embedding"], hist_ids + off)               # (..., L, d)
    low = (e @ params["S"]) * hist_mask[..., None]              # S e_i
    b = jax.lax.stop_gradient(params["b_init"])                 # (K, L), frozen
    b = jnp.broadcast_to(b, (*low.shape[:-2], cfg.n_interests, cfg.seq_len))
    neg = jnp.asarray(-1e30, low.dtype)
    for _ in range(cfg.capsule_iters):
        logits = jnp.where(hist_mask[..., None, :] > 0, b, neg)
        w = jax.nn.softmax(logits, axis=-2)                     # over interests
        caps = _squash(jnp.einsum("...kl,...ld->...kd", w * hist_mask[..., None, :], low))
        b = b + jnp.einsum("...kd,...ld->...kl", caps, low)
    return caps


def label_aware_user_vec(cfg: MINDConfig, interests: jax.Array,
                         target_e: jax.Array) -> jax.Array:
    """Label-aware attention: softmax((K e_t)^p scaled dots) combination."""
    dots = jnp.einsum("...kd,...d->...k", interests, target_e)
    attn = jax.nn.softmax(cfg.label_pow * dots, axis=-1)
    return jnp.einsum("...k,...kd->...d", attn, interests)


def loss(params: dict, cfg: MINDConfig, batch: dict, take_fn=None) -> jax.Array:
    """Sampled-softmax over {target} + n_neg sampled item ids.

    batch: hist_ids (B, L), hist_mask (B, L), target_id (B,),
           neg_ids (B, n_neg).
    """
    off = _item_arena_offset(cfg)
    take = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    interests = user_interests(params, cfg, batch["hist_ids"], batch["hist_mask"],
                               take_fn=take_fn)
    tgt_e = take(params["embedding"], batch["target_id"] + off)
    user = label_aware_user_vec(cfg, interests, tgt_e)
    neg_e = take(params["embedding"], batch["neg_ids"] + off)
    pos_logit = (user * tgt_e).sum(-1, keepdims=True)
    neg_logit = jnp.einsum("...d,...nd->...n", user, neg_e)
    logits = jnp.concatenate([pos_logit, neg_logit], axis=-1)
    return -jax.nn.log_softmax(logits, axis=-1)[..., 0].mean()


def apply(params: dict, cfg: MINDConfig, batch: dict) -> jax.Array:
    """Pointwise score of (user history, target item) — eval convenience."""
    off = _item_arena_offset(cfg)
    interests = user_interests(params, cfg, batch["hist_ids"], batch["hist_mask"])
    tgt_e = jnp.take(params["embedding"], batch["target_id"] + off, axis=0)
    return jnp.einsum("...kd,...d->...k", interests, tgt_e).max(axis=-1)


def rank_items(params: dict, cfg: MINDConfig, query: dict,
               take_fn=None) -> jax.Array:
    """Retrieval scoring: max over interests of <interest, item>.

    query: hist_ids (Bq, L), hist_mask (Bq, L), item_ids (Bq, n, 1).
    The interest extraction runs ONCE per query; per-candidate cost is a
    K x d dot — a batched (n, d) @ (d, K) matmul -> max over K.
    """
    off = _item_arena_offset(cfg)
    take = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    interests = user_interests(params, cfg, query["hist_ids"], query["hist_mask"],
                               take_fn=take_fn)
    item_e = take(params["embedding"], query["item_ids"][..., 0] + off)
    scores = jnp.einsum("...nd,...kd->...nk", item_e, interests)
    return scores.max(axis=-1)
