"""Shared parameter-pytree NN layers (no flax in this stack — by design the
substrate is part of the deliverable).  Conventions:

  * every layer is an ``init_*(rng, ...) -> params_dict`` plus a pure
    ``apply`` function
  * params are nested dicts of jnp arrays; matching PartitionSpec trees are
    produced by ``repro.sharding.rules``
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp


def glorot(rng, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = np.sqrt(2.0 / (fan_in + fan_out))
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def he(rng, shape, dtype=jnp.float32):
    scale = np.sqrt(2.0 / shape[-2])
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(rng, dims: Sequence[int], dtype=jnp.float32):
    """dims = [in, h1, ..., out]."""
    keys = jax.random.split(rng, len(dims) - 1)
    return {
        f"layer_{i}": {
            "w": he(keys[i], (dims[i], dims[i + 1]), dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        }
        for i in range(len(dims) - 1)
    }


def apply_mlp(params, x, *, activation=jax.nn.relu, final_activation=None):
    n = len(params)
    for i in range(n):
        p = params[f"layer_{i}"]
        x = x @ p["w"] + p["b"]
        if i < n - 1:
            x = activation(x)
        elif final_activation is not None:
            x = final_activation(x)
    return x


# ---------------------------------------------------------------------------
# LayerNorm / RMSNorm
# ---------------------------------------------------------------------------

def init_layer_norm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_layer_norm(params, x, eps=1e-6):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * params["scale"] + params["bias"]


def init_rms_norm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def apply_rms_norm(params, x, eps=1e-6):
    var = (x.astype(jnp.float32) ** 2).mean(axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * params["scale"]


# ---------------------------------------------------------------------------
# Dense multi-head attention over SHORT sequences (recsys fields / behavior
# history: m <= ~64).  Long-sequence LM attention lives in
# repro.models.transformer.attention (chunked / windowed / Pallas-flash).
# ---------------------------------------------------------------------------

def init_mha(rng, d_in: int, d_head: int, n_heads: int, d_out: int | None = None,
             dtype=jnp.float32):
    d_out = d_out or d_in
    k = jax.random.split(rng, 4)
    return {
        "wq": glorot(k[0], (d_in, n_heads * d_head), dtype),
        "wk": glorot(k[1], (d_in, n_heads * d_head), dtype),
        "wv": glorot(k[2], (d_in, n_heads * d_head), dtype),
        "wo": glorot(k[3], (n_heads * d_head, d_out), dtype),
    }


def apply_mha(params, x, *, n_heads: int, mask: jax.Array | None = None,
              scaled: bool = True):
    """x: (..., s, d_in) -> (..., s, d_out).  mask: (..., s, s) additive-0/1."""
    s, _ = x.shape[-2:]
    d_head = params["wq"].shape[-1] // n_heads

    def split(h):
        return h.reshape(*h.shape[:-1], n_heads, d_head)

    q = split(x @ params["wq"])
    k = split(x @ params["wk"])
    v = split(x @ params["wv"])
    logits = jnp.einsum("...shd,...thd->...hst", q, k)
    if scaled:
        logits = logits / np.sqrt(d_head)
    if mask is not None:
        logits = jnp.where(mask[..., None, :, :] > 0, logits, -1e30)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("...hst,...thd->...shd", attn, v)
    out = out.reshape(*out.shape[:-2], n_heads * d_head)
    return out @ params["wo"]
