"""Pallas TPU embedding-bag kernel: gather rows of a (large, HBM-resident)
table by data-dependent ids and reduce weighted bags.

TPU adaptation: accelerators have no cheap random HBM access from the
compute core — the gather must be expressed as per-row DMAs.  Pallas'
scalar-prefetch mechanism does exactly this: ids are a scalar-prefetch
operand, and each SLOT of each example becomes a BlockSpec view of the
table whose index_map reads ids at trace-scheduled time — the Mosaic
pipeline overlaps the row DMAs of step i+1 with the reduce of step i.

Grid = (n_examples,).  Per step: n_slots row-DMAs of (1, k) + a (F, k)
accumulate in VMEM.  HBM traffic = exactly the touched rows (the roofline
minimum for a gather), vs. jnp.take's XLA gather which materializes the
same bytes but cannot overlap with the bag reduce.

The slot->bag mapping and per-slot arena offsets are STATIC (FeatureLayout)
— they compile into the unrolled per-slot loop.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocks


def _make_kernel(n_slots: int, segment_ids, n_bags: int):
    seg = [int(s) for s in segment_ids]

    def kernel(ids_ref, w_ref, *refs):
        row_refs = refs[:n_slots]
        out_ref = refs[n_slots]
        out = jnp.zeros(out_ref.shape, out_ref.dtype)   # (1, n_bags, k)
        for s in range(n_slots):
            row = row_refs[s][0]                     # (k,)
            # weight cast to the table dtype: an f32 weight would promote
            # the product and scatter f32 into a bf16 accumulator (a hard
            # error in upcoming JAX), matching the jnp embedding_bag path.
            w = w_ref[0, s].astype(row.dtype)
            out = out.at[0, seg[s], :].add(row * w)
        out_ref[...] = out

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("segment_ids", "n_bags", "interpret"))
def embedding_bag(
    table: jax.Array,        # (rows, k)
    ids: jax.Array,          # (B, n_slots) arena-global rows
    weights: jax.Array,      # (B, n_slots)
    *,
    segment_ids: tuple,      # static slot -> bag map
    n_bags: int,
    interpret: bool = False,
) -> jax.Array:
    B, n_slots = ids.shape
    rows, k = table.shape
    kernel = _make_kernel(n_slots, segment_ids, n_bags)

    # one BlockSpec view of the table per slot: view s of grid step i DMAs
    # table row ids[i, s] into VMEM (scalar-prefetch drives the index_map).
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            blocks.prefetch_batch(n_slots),              # weights
            *blocks.prefetch_rows(n_slots, k),
        ],
        out_specs=blocks.prefetch_batch(n_bags, k),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_bags, k), table.dtype),
        interpret=interpret,
    )(ids, weights, *([table] * n_slots))
    return out
