"""Pure-jnp oracles for every Pallas kernel (the tests sweep shapes/dtypes
and assert_allclose kernel-vs-ref)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def dplr_score_items_ref(V_I, U_I, e, d_I, P_C, s_C):
    P = P_C[None] + jnp.einsum("rm,nmk->nrk", U_I, V_I)
    term_e = jnp.einsum("nrk,r->n", P * P, e)
    term_d = jnp.einsum("nmk,m->n", V_I * V_I, d_I)
    return 0.5 * (s_C + term_d + term_e)


def dplr_corpus_score_ref(Q_I, a_I, e, P_C, a_C, valid=None):
    """(Bq, n) corpus-cached scores: a_C + a_I + 0.5 e.||P_C + Q_I||^2,
    with dead slots (``valid[i] == False``) pinned to the kernel's
    NEG_INF sentinel."""
    from repro.kernels.dplr_corpus_score import NEG_INF
    P = P_C[:, None] + Q_I[None]
    term_e = jnp.einsum("qnrk,r->qn", P * P, e)
    s = a_C[:, None] + a_I[None, :] + 0.5 * term_e
    if valid is not None:
        s = jnp.where(jnp.asarray(valid)[None, :], s, NEG_INF)
    return s


def dplr_corpus_topk_ref(Q_I, a_I, e, P_C, a_C, topk, valid=None,
                         index_offset=0, index_stride=1):
    """argsort-based top-K oracle: ((Bq, K) scores, (Bq, K) indices).

    ``index_offset``/``index_stride`` relabel local row ``i`` as
    ``offset + stride * i`` — the sharded slab's striped global slot ids
    (mirrors the kernel's shard-local index semantics)."""
    s = dplr_corpus_score_ref(Q_I, a_I, e, P_C, a_C, valid)
    idx = jnp.argsort(-s, axis=1)[:, :topk].astype(jnp.int32)
    vals = jnp.take_along_axis(s, idx, axis=1)
    return vals, index_offset + index_stride * idx


def dplr_corpus_multi_topk_ref(Q_parts, a_parts, valid_parts, e, P_C, a_C,
                               topk, index_offset=0, index_stride=1):
    """Tenant-segmented top-K oracle: the fused multi-segment kernel must
    equal S independent single-segment top-K passes stacked to
    ``((S, Bq, K) scores, (S, Bq, K) indices)`` — segment ``s`` scored
    only against its own corpus part with its own eigen-weights, indices
    segment-local before the offset/stride relabel."""
    if valid_parts is None:
        valid_parts = (None,) * len(Q_parts)
    vals, idx = zip(*(
        dplr_corpus_topk_ref(Q_parts[s], a_parts[s], e[s], P_C[s], a_C[s],
                             topk, valid_parts[s], index_offset,
                             index_stride)
        for s in range(len(Q_parts))))
    return jnp.stack(vals), jnp.stack(idx)


def fwfm_pairwise_ref(V, R):
    G = jnp.einsum("bik,bjk->bij", V, V)
    return 0.5 * jnp.einsum("bij,ij->b", G, R)


def embedding_bag_ref(table, ids, weights, segment_ids, n_bags):
    flat = jnp.take(table, ids, axis=0)
    weighted = flat * weights[..., None].astype(flat.dtype)
    out = jnp.zeros((ids.shape[0], n_bags, table.shape[-1]), flat.dtype)
    return out.at[:, np.asarray(segment_ids), :].add(weighted)


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """(B, S, H, hd) x (B, S, KV, hd) GQA reference in f32."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    logits = logits / np.sqrt(hd)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd)


# The declared kernel -> oracle contract.  Every Pallas entry point in
# this package MUST appear here (tools/analyze rule KRN-ORACLE checks
# the map statically; tests/test_kernels.py sweeps each pair).  A kernel
# with two output modes maps to a tuple of oracles.
ORACLES = {
    "dplr_score_items": (dplr_score_items_ref,),
    "dplr_corpus_score": (dplr_corpus_score_ref, dplr_corpus_topk_ref),
    "dplr_corpus_score_multi": (dplr_corpus_multi_topk_ref,),
    "fwfm_pairwise": (fwfm_pairwise_ref,),
    "embedding_bag": (embedding_bag_ref,),
    "flash_attention": (flash_attention_ref,),
}
