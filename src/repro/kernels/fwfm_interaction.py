"""Pallas TPU kernel for the FULL FwFM pairwise term (the baseline the
paper accelerates): for each example, 0.5 * <V V^T, R> with symmetric
zero-diagonal R.

Tiling: a block of ``block_b`` examples' field matrices (block_b, m, k)
lives in VMEM; the Gram contraction runs as one batched dot_general on the
MXU (m <= ~128 so a whole m x m Gram tile fits one MXU pass); R stays
VMEM-resident across all blocks.  O(m^2 k) per example — the cost whose
removal is the paper's contribution; this kernel exists so the baseline is
as fast as it can be on TPU (the comparison in benchmarks/fig1 is fair).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import blocks


def _kernel(v_ref, r_ref, out_ref):
    v = v_ref[...]                       # (bb, m, k)
    r = r_ref[...]                       # (m, m)
    g = jax.lax.dot_general(
        v, v,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                    # (bb, m, m)
    out_ref[...] = 0.5 * jnp.einsum("bij,ij->b", g, r)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fwfm_pairwise(
    V: jax.Array,      # (B, m, k)
    R: jax.Array,      # (m, m) symmetric, zero diagonal
    *,
    block_b: int = blocks.PAIRWISE_TILE_B,
    interpret: bool = False,
) -> jax.Array:
    B, m, k = V.shape
    block_b = blocks.clamp_tile(block_b, B)
    pad = blocks.pad_amount(B, block_b)
    if pad:
        V = jnp.pad(V, ((0, pad), (0, 0), (0, 0)))
    B_pad = V.shape[0]

    out = pl.pallas_call(
        _kernel,
        grid=blocks.grid_1d(B_pad, block_b),
        in_specs=[
            blocks.row_tiles(block_b, m, k),
            blocks.broadcast(m, m),
        ],
        out_specs=blocks.row_tiles(block_b),
        out_shape=jax.ShapeDtypeStruct((B_pad,), jnp.float32),
        interpret=interpret,
    )(V, R)
    return out[:B]
