"""Public jit'd wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (Pallas has no
CPU lowering); on TPU ``interpret=False`` compiles through Mosaic.  The
wrappers pick that automatically and expose the same signatures as the
pure-jnp references, so the serving stack can swap implementations with a
flag (cfg.use_pallas_kernels).
"""
from __future__ import annotations

import jax

from repro.kernels import blocks
from repro.kernels import dplr_corpus_score as _corpus
from repro.kernels import dplr_score as _dplr
from repro.kernels import embedding_bag as _bag
from repro.kernels import flash_attention as _flash
from repro.kernels import fwfm_interaction as _fwfm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def dplr_score_items(V_I, U_I, e, d_I, P_C, s_C, *,
                     block_n: int = blocks.ITEM_TILE_N,
                     interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _dplr.dplr_score_items(V_I, U_I, e, d_I, P_C, s_C,
                                  block_n=block_n, interpret=interp)


def dplr_corpus_score(Q_I, a_I, e, P_C, a_C, valid=None, *, topk=None,
                      block_n: int = blocks.CORPUS_TILE_N,
                      interpret: bool | None = None,
                      index_offset=0, index_stride: int = 1):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _corpus.dplr_corpus_score(Q_I, a_I, e, P_C, a_C, valid,
                                     topk=topk, block_n=block_n,
                                     interpret=interp,
                                     index_offset=index_offset,
                                     index_stride=index_stride)


def fwfm_pairwise(V, R, *, block_b: int = blocks.PAIRWISE_TILE_B,
                  interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _fwfm.fwfm_pairwise(V, R, block_b=block_b, interpret=interp)


def embedding_bag(table, ids, weights, *, segment_ids, n_bags,
                  interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _bag.embedding_bag(table, ids, weights,
                              segment_ids=tuple(int(s) for s in segment_ids),
                              n_bags=n_bags, interpret=interp)


def flash_attention(q, k, v, *, window=None, block_q=blocks.ATTN_TILE,
                    block_k=blocks.ATTN_TILE,
                    interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _flash.flash_attention(q, k, v, window=window, block_q=block_q,
                                  block_k=block_k, interpret=interp)
