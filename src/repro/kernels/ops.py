"""Public jit'd wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (Pallas has no
CPU lowering); on TPU ``interpret=False`` compiles through Mosaic.  The
wrappers pick that automatically and expose the same signatures as the
pure-jnp references, so the serving stack can swap implementations with a
flag (cfg.use_pallas_kernels).

Corpus-scorer calls that leave ``block_n=None`` (the default) resolve
their tile geometry through ``blocks.corpus_tile`` — the registry
``kernels/autotune.py`` fills with parity-gated winners — so every call
site (single-device runtime, sharded bodies, fused multi-segment path)
inherits tuned tiles without threading a flag.  Resolution happens at
Python time in the wrapper, BEFORE the jitted kernel: same shapes +
same registry = same static args = zero retraces; tune before warmup.
"""
from __future__ import annotations

import jax

from repro.kernels import blocks
from repro.kernels import dplr_corpus_score as _corpus
from repro.kernels import dplr_score as _dplr
from repro.kernels import embedding_bag as _bag
from repro.kernels import flash_attention as _flash
from repro.kernels import fwfm_interaction as _fwfm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def dplr_score_items(V_I, U_I, e, d_I, P_C, s_C, *,
                     block_n: int = blocks.ITEM_TILE_N,
                     interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _dplr.dplr_score_items(V_I, U_I, e, d_I, P_C, s_C,
                                  block_n=block_n, interpret=interp)


def _resolve_tile(n, rho, k, Bq, K, dtype, block_n, acc_dtype):
    """Explicit ``block_n``/``acc_dtype`` win; ``None`` falls through to
    the autotuner registry (default-identical when nothing is tuned)."""
    tuned_bn, tuned_acc = blocks.corpus_tile(
        n, rho, k, Bq, K, str(dtype), jax.default_backend())
    return (tuned_bn if block_n is None else block_n,
            tuned_acc if acc_dtype is None else acc_dtype)


def dplr_corpus_score(Q_I, a_I, e, P_C, a_C, valid=None, *, topk=None,
                      block_n: int | None = None,
                      interpret: bool | None = None,
                      index_offset=0, index_stride: int = 1,
                      acc_dtype: str | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    n, rho, k = Q_I.shape
    block_n, acc_dtype = _resolve_tile(n, rho, k, P_C.shape[0], topk,
                                       Q_I.dtype, block_n, acc_dtype)
    return _corpus.dplr_corpus_score(Q_I, a_I, e, P_C, a_C, valid,
                                     topk=topk, block_n=block_n,
                                     interpret=interp,
                                     index_offset=index_offset,
                                     index_stride=index_stride,
                                     acc_dtype=acc_dtype)


def dplr_corpus_score_multi(Q_parts, a_parts, valid_parts, e, P_C, a_C, *,
                            topk: int, block_n: int | None = None,
                            interpret: bool | None = None,
                            index_offset=0, index_stride: int = 1,
                            acc_dtype: str | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    if not Q_parts:
        raise ValueError("dplr_corpus_score_multi needs >= 1 segment")
    # the fused launch reuses the largest segment's tuned cell (its tiles
    # dominate the grid); per-segment retuning would fragment block_n
    n, rho, k = max((q.shape for q in Q_parts), key=lambda s: s[0])
    block_n, acc_dtype = _resolve_tile(n, rho, k, P_C.shape[1], topk,
                                       Q_parts[0].dtype, block_n, acc_dtype)
    return _corpus.dplr_corpus_score_multi(
        tuple(Q_parts), tuple(a_parts),
        valid_parts if valid_parts is None else tuple(valid_parts),
        e, P_C, a_C, topk=topk, block_n=block_n, interpret=interp,
        index_offset=index_offset, index_stride=index_stride,
        acc_dtype=acc_dtype)


def fwfm_pairwise(V, R, *, block_b: int = blocks.PAIRWISE_TILE_B,
                  interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _fwfm.fwfm_pairwise(V, R, block_b=block_b, interpret=interp)


def embedding_bag(table, ids, weights, *, segment_ids, n_bags,
                  interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _bag.embedding_bag(table, ids, weights,
                              segment_ids=tuple(int(s) for s in segment_ids),
                              n_bags=n_bags, interpret=interp)


def flash_attention(q, k, v, *, window=None, block_q=blocks.ATTN_TILE,
                    block_k=blocks.ATTN_TILE,
                    interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _flash.flash_attention(q, k, v, window=window, block_q=block_q,
                                  block_k=block_k, interpret=interp)
