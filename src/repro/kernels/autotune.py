"""Shape-cell autotuner for ``dplr_corpus_score`` (+ the hardware table).

The corpus scorer historically ran one hand-picked tile
(``blocks.CORPUS_TILE_N``) and f32 accumulation for every shape and
dtype.  This module sweeps the tile size — and bf16 score accumulation
where the slab dtype already is bf16 — per ``(n, rho, k, Bq, K, dtype,
backend)`` cell, gates EVERY candidate on the ref oracles (a faster
wrong kernel never wins), and registers the winner in
``blocks.register_tuned_tile`` so every call site that leaves
``block_n=None`` (runtime, sharded bodies, fused multi-segment path)
inherits it with zero retraces — provided tuning runs BEFORE warmup,
because the registry is consulted when the calling jit traces.

Parity gates (per candidate, never sampled):

  * f32 accumulation — indices EXACTLY equal to ``dplr_corpus_topk_ref``
    and values allclose at f32 epsilon: the tile size must be
    numerically invisible.
  * bf16 accumulation — the returned indices must select items whose
    REF scores are within ``bf16_tol`` of the ref top-K values (rank
    displacement is allowed only between near-ties the tolerance
    covers); returned values must match the ref scores of the returned
    items within the same tolerance.

Clamp visibility: candidates larger than ``n`` are clamped by
``blocks.clamp_tile``; the events are drained per candidate and carried
on the result so benchmarks report requested-vs-effective divergence
instead of hiding it (the "no silent caps" rule).

``HW_PROFILES`` is the single named source of per-chip peak numbers —
``benchmarks/roofline.py`` binds its ``PEAK_FLOPS``/``HBM_BW``/``ICI_BW``
from here (``--hw`` flag) and the autotuner uses the same profile to
report each winner's distance from the memory roofline.

In-process results cache per cell; ``save_cache``/``load_cache``
round-trip the registry through a small JSON file so a warm process can
skip the sweep entirely.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass

import numpy as np

from repro.kernels import blocks

# Named per-chip peak numbers (public spec-sheet values; bf16 FLOPs).
# The profile feeds both the roofline benchmark and the autotuner's
# bandwidth reporting — one table, two consumers.
HW_PROFILES: dict[str, dict[str, float]] = {
    "v5e": {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9},
    "v4": {"peak_flops": 275e12, "hbm_bw": 1228e9, "ici_bw": 100e9},
    "v5p": {"peak_flops": 459e12, "hbm_bw": 2765e9, "ici_bw": 100e9},
    # interpret-mode CPU numbers are deliberately rough: the autotuner
    # only uses them for reporting, never for picking a winner
    "cpu": {"peak_flops": 1e11, "hbm_bw": 5e10, "ici_bw": 1e9},
}
DEFAULT_HW = "v5e"

# Default tile sweep: the named default plus its pow2 neighbours.  Cells
# smaller than a candidate clamp (visibly — see clamp events).
DEFAULT_CANDIDATES = (512, 1024, 2048, 4096, 8192)


@dataclass(frozen=True)
class CandidateResult:
    """One swept (block_n, acc_dtype) configuration of a cell."""
    block_n: int                # requested tile
    effective_block_n: int      # after clamp_tile (== block_n when n >= tile)
    acc_dtype: str
    us: float                   # best-of-repeats wall time, microseconds
    parity_ok: bool
    parity_error: str | None = None
    clamps: tuple = ()          # drained blocks.drain_clamp_events dicts


@dataclass(frozen=True)
class TunedTile:
    """A cell's sweep outcome: the parity-gated winner vs the default."""
    cell: tuple                 # blocks.tile_cell key
    block_n: int
    acc_dtype: str
    us: float                   # winner's time
    default_us: float           # CORPUS_TILE_N/f32 time on the same cell
    swept: tuple = ()           # every CandidateResult, winners and losers
    hw: str = DEFAULT_HW
    bytes_per_call: int = 0     # slab + output traffic, for roofline frac

    @property
    def speedup(self) -> float:
        return self.default_us / self.us if self.us > 0 else 1.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the profile's HBM roofline the winner achieves
        (reporting only — meaningless in interpret mode, honest on TPU)."""
        bw = HW_PROFILES[self.hw]["hbm_bw"]
        ideal_us = self.bytes_per_call / bw * 1e6
        return ideal_us / self.us if self.us > 0 else 0.0


# in-process memo: tile_cell -> TunedTile (sweeps are not free; a warmup
# that touches the same cell twice must pay once)
_RESULTS: dict[tuple, TunedTile] = {}


def clear_results() -> None:
    """Drop the in-process sweep memo (tests / benchmark hygiene)."""
    _RESULTS.clear()


def _mk_inputs(n, rho, k, Bq, dtype, seed):
    r = np.random.default_rng(seed)
    Q = r.normal(size=(n, rho, k)).astype(dtype)
    a = r.normal(size=(n,)).astype(np.float32)
    e = r.normal(size=(rho,)).astype(np.float32)
    P = r.normal(size=(Bq, rho, k)).astype(dtype)
    aC = r.normal(size=(Bq,)).astype(np.float32)
    valid = (r.random(n) > 0.1)
    valid[: max(1, n // 8)] = True      # K live items guaranteed
    return Q, a, e, P, aC, valid


def _check_parity(vals, idx, ref_scores, ref_vals, acc_dtype, ref_idx,
                  bf16_tol):
    """Gate one candidate's output against the oracle.  Returns an error
    string (None = pass)."""
    vals = np.asarray(vals)
    idx = np.asarray(idx)
    if acc_dtype == "float32":
        if not np.array_equal(idx, ref_idx):
            return "f32 indices diverge from dplr_corpus_topk_ref"
        if not np.allclose(vals, ref_vals, rtol=1e-5, atol=1e-5):
            return "f32 values beyond epsilon of dplr_corpus_topk_ref"
        return None
    # bf16 accumulation: judge the returned ITEMS by their ref scores
    got = np.take_along_axis(ref_scores, idx, axis=1)
    if not np.allclose(got, ref_vals, rtol=0, atol=bf16_tol):
        return "bf16 indices select items outside tolerance of ref top-K"
    if not np.allclose(vals, got, rtol=0, atol=bf16_tol):
        return "bf16 values beyond tolerance of the selected items' ref"
    return None


def _time_call(fn, repeats: int) -> float:
    """Best-of-repeats microseconds; each call blocks on the result."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        for leaf in out if isinstance(out, tuple) else (out,):
            leaf.block_until_ready()
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def tune_corpus_score(n: int, rho: int, k: int, Bq: int, K: int, *,
                      dtype: str = "float32",
                      candidates=DEFAULT_CANDIDATES,
                      sweep_bf16_acc: bool | None = None,
                      bf16_tol: float = 5e-2,
                      repeats: int = 3, seed: int = 0,
                      register: bool = True, hw: str = DEFAULT_HW,
                      interpret: bool | None = None) -> TunedTile:
    """Sweep ``dplr_corpus_score`` tiles for one shape cell and return
    the parity-gated winner (registered into ``blocks`` unless
    ``register=False``).

    ``sweep_bf16_acc=None`` (default) sweeps bf16 accumulation exactly
    when the slab ``dtype`` is bfloat16 — a f32 slab never trades
    accumulation precision.  Every swept configuration is oracle-gated;
    a candidate that fails parity is recorded (``parity_ok=False``) and
    excluded from the podium no matter how fast it ran.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.ref import dplr_corpus_score_ref, dplr_corpus_topk_ref

    if hw not in HW_PROFILES:
        raise ValueError(f"unknown hw profile {hw!r}; "
                         f"have {sorted(HW_PROFILES)}")
    # the cell's backend key MUST be what ops._resolve_tile uses at the
    # real call sites, or registered winners would never be looked up
    cell = blocks.tile_cell(n, rho, k, Bq, K, dtype, jax.default_backend())
    hit = _RESULTS.get(cell)
    if hit is not None:
        if register:
            blocks.register_tuned_tile(cell, hit.block_n, hit.acc_dtype)
        return hit

    Q, a, e, P, aC, valid = _mk_inputs(n, rho, k, Bq, dtype, seed)
    ref_scores = np.asarray(dplr_corpus_score_ref(
        jnp.asarray(Q, jnp.float32), a, e,
        jnp.asarray(P, jnp.float32), aC, valid))
    rv, ri = dplr_corpus_topk_ref(
        jnp.asarray(Q, jnp.float32), a, e,
        jnp.asarray(P, jnp.float32), aC, K, valid)
    ref_vals, ref_idx = np.asarray(rv), np.asarray(ri)

    if sweep_bf16_acc is None:
        sweep_bf16_acc = jnp.dtype(dtype) == jnp.bfloat16
    accs = ("float32", "bfloat16") if sweep_bf16_acc else ("float32",)

    sweep = dict.fromkeys(candidates)       # ordered, deduped
    sweep[blocks.CORPUS_TILE_N] = None      # the default always competes
    results: list[CandidateResult] = []
    for bn in sweep:
        for acc in accs:
            blocks.drain_clamp_events()     # isolate this candidate's
            call = lambda: ops.dplr_corpus_score(    # noqa: E731
                Q, a, e, P, aC, valid=valid, topk=K, block_n=bn,
                interpret=interpret, acc_dtype=acc)
            vals, idx = call()
            clamps = tuple(blocks.drain_clamp_events())
            err = _check_parity(vals, idx, ref_scores, ref_vals, acc,
                                ref_idx, bf16_tol)
            us = _time_call(call, repeats) if err is None else float("inf")
            results.append(CandidateResult(
                block_n=bn, effective_block_n=min(bn, n), acc_dtype=acc,
                us=us, parity_ok=err is None, parity_error=err,
                clamps=clamps))

    passing = [r for r in results if r.parity_ok]
    if not passing:
        raise RuntimeError(
            f"autotune cell {cell}: no candidate passed the parity gate")
    winner = min(passing, key=lambda r: r.us)
    default_us = min(r.us for r in passing
                     if r.block_n == blocks.CORPUS_TILE_N
                     and r.acc_dtype == "float32")
    itemsize = jnp.dtype(dtype).itemsize
    slab_bytes = n * rho * k * itemsize + n * (itemsize + 1)
    out_bytes = Bq * K * 8 + Bq * rho * k * itemsize
    tuned = TunedTile(cell=cell, block_n=winner.block_n,
                      acc_dtype=winner.acc_dtype, us=winner.us,
                      default_us=default_us, swept=tuple(results), hw=hw,
                      bytes_per_call=slab_bytes + out_bytes)
    _RESULTS[cell] = tuned
    if register:
        blocks.register_tuned_tile(cell, tuned.block_n, tuned.acc_dtype)
    return tuned


# -- optional on-disk registry cache ----------------------------------------

def save_cache(path) -> int:
    """Write every in-process sweep winner to ``path`` (JSON).  Returns
    the number of cells written."""
    payload = {json.dumps(t.cell): {"block_n": t.block_n,
                                    "acc_dtype": t.acc_dtype,
                                    "us": t.us,
                                    "default_us": t.default_us}
               for t in _RESULTS.values()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return len(payload)


def load_cache(path, *, register: bool = True) -> int:
    """Re-register winners from a ``save_cache`` file (a warm process
    skips the sweep).  Returns the number of cells loaded; silently 0
    when the file does not exist — a cold cache is not an error."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except FileNotFoundError:
        return 0
    for cell_s, rec in payload.items():
        cell = tuple(json.loads(cell_s))
        if register:
            blocks.register_tuned_tile(cell, int(rec["block_n"]),
                                       str(rec["acc_dtype"]))
    return len(payload)
