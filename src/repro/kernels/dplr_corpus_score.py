"""Pallas TPU kernel for corpus-precomputed DPLR-FwFM scoring (+ fused top-K).

This is the serving-engine hot op.  Everything item-side is context-
independent, so it is PRECOMPUTED into the mutable corpus slab
(``repro.serving.corpus``) — once per model refresh for the full slab,
per-row for churn deltas:

    Q_I[i] = U_I @ V_I[i]                  (rho, k)   rank-space projection
    a_I[i] = lin_I[i] + 0.5 * t_I[i]       ()         per-item scalar addend

Per (query q, item i) the score is then

    score[q, i] = a_C[q] + a_I[i] + 0.5 * sum_r e_r ||P_C[q, r] + Q_I[i, r]||^2

with ``P_C (Bq, rho, k)`` / ``a_C (Bq,)`` the per-query context cache.  The
kernel tiles the ITEM axis: one grid step holds a ``(block_n, rho, k)``
slab of Q_I in VMEM, so HBM traffic is ONE pass over ``(n, rho, k)`` —
strictly less than the ``(n, m_I, k)`` pass of ``dplr_score.py`` (the
Algorithm-1 kernel that still re-projects item embeddings per query), by
the factor m_I / rho (~12x for the paper's deployed geometry).

Two output modes:
  * full   — ``(Bq, n)`` logits, out block revisited per item tile.
  * top-K  — running per-query top-K carried in the OUTPUT blocks across
    grid steps (constant index_map => the block stays resident in VMEM);
    each step merges its tile's scores into the running (values, indices)
    pair, so only ``(Bq, K)`` floats + ints ever leave the scorer.  The
    merge uses ``jax.lax.top_k`` on the ``(Bq, K + block_n)`` concat —
    supported in interpret mode; on Mosaic a bitonic merge may be needed
    for very old toolchains.

Validity mask: the serving corpus is a capacity-padded MUTABLE slab
(``repro.serving.corpus``), so the kernel takes an optional ``valid`` (n,)
mask and pins dead slots to exactly ``NEG_INF`` inside each tile — before
the running top-K merge — so a dead (or phantom-padding) slot can never win
a top-K slot.  Padding: n is padded up to a block multiple with
``valid = 0`` phantom rows; the full mode slices them off.

Shard-local semantics: when the slab is sharded across a device mesh
(``repro.serving.sharded``), each shard calls this kernel on its LOCAL
(n/D, rho, k) slice with its LOCAL validity mask — masking is a per-shard
concern and needs no cross-device view.  The top-K indices the kernel
emits, however, must be mesh-GLOBAL so the D-way candidate merge can
compare them; ``index_offset``/``index_stride`` relabel row ``i`` of the
local slice as ``index_offset + index_stride * i`` inside the running
top-K (striped slot ownership uses ``offset=shard, stride=D``; the
single-device engine keeps the identity labeling 0,1,2,...).

Accumulation dtype: ``acc_dtype='bfloat16'`` runs the O(Bq n rho k)
eigen-weighted square-sum reduction in bf16 (halving the MXU/VPU input
traffic where the slab dtype already sacrificed the precision) and
upcasts to f32 BEFORE masking and the running top-K merge, so sentinel
comparisons and tie-breaking stay exact.  The default ``'float32'`` is
byte-identical to the historical kernel.  The autotuner sweeps this
knob only for bf16 slabs; scores are tolerance-gated, not bit-exact.

Multi-segment mode: ``dplr_corpus_score_multi`` scores S tenants'
micro-batches in ONE launch.  The per-segment corpus slabs concatenate
on the item axis (each padded to a whole number of tiles), the S
micro-batches stack into one (S*Bq, ...) context block, and a static
per-tile ``(q_off, q_len, row_base)`` table tells each grid step which
query rows its tile's segment owns: rows outside the window are pinned
to NEG_INF before the running top-K merge, so a segment's top-K can
NEVER surface a neighbor segment's slot, and emitted indices are
segment-LOCAL (``row_base`` restarts at 0 per segment) relabeled by the
same ``index_offset``/``index_stride`` rule as the single-tenant mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import blocks

NEG_INF = -1e30


def _einsum_acc(spec, pp, e, acc_dtype):
    """The eigen-weighted reduction, in the requested accumulation dtype
    (f32 path untouched — bit-identical to the historical kernel)."""
    if acc_dtype == jnp.float32:
        return jnp.einsum(spec, pp, e)
    return jnp.einsum(spec, pp.astype(acc_dtype),
                      e.astype(acc_dtype)).astype(jnp.float32)


def _tile_scores(q, a_i, e, pc, a_c, m, acc_dtype=jnp.float32):
    """(Bq, block_n) scores for one item tile.  All operands f32 in VMEM;
    ``m`` is the tile's (block_n,) {0,1} validity mask — dead slots are
    pinned to exactly NEG_INF so they can never win a top-K slot."""
    # p: (Bq, bn, rho, k) — direct fused form, same reduction order as the
    # jnp reference so corpus-cached parity stays at float32 epsilon.
    p = pc[:, None, :, :] + q[None, :, :, :]
    term_e = _einsum_acc("qnrk,r->qn", p * p, e, acc_dtype)
    s = a_c[:, None] + a_i[None, :] + 0.5 * term_e
    return jnp.where((m != 0)[None, :], s, NEG_INF)


def _kernel_full(q_ref, a_ref, e_ref, pc_ref, ac_ref, m_ref, out_ref, *,
                 acc_dtype):
    out_ref[...] = _tile_scores(
        q_ref[...], a_ref[:, 0], e_ref[:, 0], pc_ref[...], ac_ref[:, 0],
        m_ref[:, 0], acc_dtype)


def _kernel_topk(q_ref, a_ref, e_ref, pc_ref, ac_ref, m_ref, off_ref,
                 val_ref, idx_ref, *, block_n: int, topk: int,
                 index_stride: int, acc_dtype):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    scores = _tile_scores(
        q_ref[...], a_ref[:, 0], e_ref[:, 0], pc_ref[...], ac_ref[:, 0],
        m_ref[:, 0], acc_dtype)
    # row r of this tile is local slot i*block_n + r; the emitted index is
    # its caller-defined global label off + stride * local.
    tile_idx = off_ref[0, 0] + index_stride * (
        i * block_n + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))
    cat_v = jnp.concatenate([val_ref[...], scores], axis=1)
    cat_i = jnp.concatenate([idx_ref[...], tile_idx], axis=1)
    top_v, top_pos = jax.lax.top_k(cat_v, topk)
    val_ref[...] = top_v
    idx_ref[...] = jnp.take_along_axis(cat_i, top_pos, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("topk", "block_n", "interpret",
                                    "index_stride", "acc_dtype"))
def dplr_corpus_score(
    Q_I: jax.Array,    # (n, rho, k)  precomputed item projections
    a_I: jax.Array,    # (n,)         per-item scalar (lin_I + 0.5 * t_I)
    e: jax.Array,      # (rho,)       DPLR eigen-weights
    P_C: jax.Array,    # (Bq, rho, k) cached context projections
    a_C: jax.Array,    # (Bq,)        per-query scalar (b0 + lin_C + 0.5*s_C)
    valid: jax.Array | None = None,   # (n,) slot liveness; None = all live
    *,
    topk: int | None = None,
    block_n: int = blocks.CORPUS_TILE_N,
    interpret: bool = False,
    index_offset: jax.Array | int = 0,
    index_stride: int = 1,
    acc_dtype: str = "float32",
):
    """Corpus-cached batched scorer.  Returns ``(Bq, n)`` scores (dead
    slots exactly ``NEG_INF``), or with ``topk=K`` the fused ``((Bq, K)
    scores, (Bq, K) int32 indices)`` over LIVE slots only.

    ``index_offset``/``index_stride`` relabel the top-K indices: local row
    ``i`` reports as ``index_offset + index_stride * i`` (used by the
    sharded slab, whose shard ``s`` of ``D`` owns the striped global slots
    ``s, s + D, s + 2D, ...``).  ``index_offset`` may be traced (e.g. an
    ``axis_index`` inside ``shard_map``); the stride is static.

    ``acc_dtype``: accumulation dtype of the rank-space reduction
    (``'float32'`` default = historical bit-exact path; ``'bfloat16'``
    trades the reduction's precision for bandwidth — autotuner-gated,
    tolerance-bounded vs the oracle, never used on f32 slabs)."""
    n, rho, k = Q_I.shape
    Bq = P_C.shape[0]
    acc = jnp.dtype(acc_dtype)
    Q_I = Q_I.astype(jnp.float32)
    a_I = a_I.astype(jnp.float32)
    e = e.astype(jnp.float32)
    P_C = P_C.astype(jnp.float32)
    a_C = a_C.astype(jnp.float32)
    mask = (jnp.ones((n,), jnp.int32) if valid is None
            else jnp.asarray(valid).astype(jnp.int32))

    block_n = blocks.clamp_tile(block_n, n)
    pad = blocks.pad_amount(n, block_n)
    if pad:
        Q_I = jnp.pad(Q_I, ((0, pad), (0, 0), (0, 0)))
        a_I = jnp.pad(a_I, (0, pad))
        mask = jnp.pad(mask, (0, pad))      # phantom rows are dead slots
    n_pad = n + pad
    grid = blocks.grid_1d(n_pad, block_n)

    in_specs = [
        blocks.row_tiles(block_n, rho, k),
        blocks.row_tiles(block_n, 1),
        blocks.broadcast(rho, 1),
        blocks.broadcast(Bq, rho, k),
        blocks.broadcast(Bq, 1),
        blocks.row_tiles(block_n, 1),
    ]
    args = (Q_I, a_I[:, None], e[:, None], P_C, a_C[:, None], mask[:, None])

    if topk is None:
        return pl.pallas_call(
            functools.partial(_kernel_full, acc_dtype=acc),
            grid=grid,
            in_specs=in_specs,
            out_specs=blocks.col_tiles(Bq, block_n),
            out_shape=jax.ShapeDtypeStruct((Bq, n_pad), jnp.float32),
            interpret=interpret,
        )(*args)[:, :n]

    if not 0 < topk <= n:
        raise ValueError(f"topk={topk} out of range for n={n}")
    off = jnp.asarray(index_offset, jnp.int32).reshape(1, 1)
    in_specs = in_specs + [blocks.broadcast(1, 1)]
    args = args + (off,)
    kernel = functools.partial(_kernel_topk, block_n=block_n, topk=topk,
                               index_stride=index_stride, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            # constant index map => the running (values, indices) pair
            # stays VMEM-resident across every item tile
            blocks.broadcast(Bq, topk),
            blocks.broadcast(Bq, topk),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bq, topk), jnp.float32),
            jax.ShapeDtypeStruct((Bq, topk), jnp.int32),
        ],
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Multi-segment mode: S tenants' micro-batches in ONE launch
# ---------------------------------------------------------------------------

def _tile_scores_multi(q, a_i, e_q, pc, a_c, m, acc_dtype=jnp.float32):
    """(SB, block_n) scores of one item tile against EVERY stacked query
    row — the per-query ``e_q`` carries each row's own segment's eigen-
    weights, so foreign rows compute garbage that the caller masks to
    NEG_INF before the merge (they can never win a slot)."""
    p = pc[:, None, :, :] + q[None, :, :, :]
    term_e = _einsum_acc("qnrk,qr->qn", p * p, e_q, acc_dtype)
    s = a_c[:, None] + a_i[None, :] + 0.5 * term_e
    return jnp.where((m != 0)[None, :], s, NEG_INF)


def _kernel_multi_topk(q_ref, a_ref, m_ref, meta_ref, eq_ref, pc_ref,
                       ac_ref, off_ref, val_ref, idx_ref, *, topk: int,
                       index_stride: int, acc_dtype):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    scores = _tile_scores_multi(
        q_ref[...], a_ref[:, 0], eq_ref[...], pc_ref[...], ac_ref[:, 0],
        m_ref[:, 0], acc_dtype)
    # the tile's static metadata row: which stacked query rows this
    # tile's segment owns, and the tile's first segment-LOCAL item row
    q_off, q_len, row_base = meta_ref[0, 0], meta_ref[0, 1], meta_ref[0, 2]
    qidx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    own = (qidx >= q_off) & (qidx < q_off + q_len)
    # a foreign row sees this tile as all-NEG_INF, so its running top-K
    # is untouched by neighbor segments' item tiles (segment isolation)
    scores = jnp.where(own, scores, NEG_INF)
    tile_idx = off_ref[0, 0] + index_stride * (
        row_base + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))
    cat_v = jnp.concatenate([val_ref[...], scores], axis=1)
    cat_i = jnp.concatenate([idx_ref[...], tile_idx], axis=1)
    top_v, top_pos = jax.lax.top_k(cat_v, topk)
    val_ref[...] = top_v
    idx_ref[...] = jnp.take_along_axis(cat_i, top_pos, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("topk", "block_n", "interpret",
                                    "index_stride", "acc_dtype"))
def dplr_corpus_score_multi(
    Q_parts: tuple,    # S x (n_s, rho, k) per-segment item projections
    a_parts: tuple,    # S x (n_s,)        per-segment item scalars
    valid_parts,       # S x (n_s,) liveness masks, or None = all live
    e: jax.Array,      # (S, rho)          per-segment eigen-weights
    P_C: jax.Array,    # (S, Bq, rho, k)   stacked context projections
    a_C: jax.Array,    # (S, Bq)           stacked per-query scalars
    *,
    topk: int,
    block_n: int = blocks.CORPUS_TILE_N,
    interpret: bool = False,
    index_offset: jax.Array | int = 0,
    index_stride: int = 1,
    acc_dtype: str = "float32",
):
    """Tenant-segmented fused top-K: scores S segments' micro-batches in
    ONE kernel launch and returns ``((S, Bq, topk) scores, (S, Bq, topk)
    int32 indices)`` — row ``[s, q]`` is bitwise the running top-K of
    segment ``s``'s corpus alone (foreign tiles contribute only NEG_INF,
    which ``lax.top_k``'s lowest-position tie-break can never promote
    over a live score while ``topk <= n_live(s)``).

    Indices are segment-LOCAL slots relabeled by ``index_offset``/
    ``index_stride`` (same striping rule as the single-segment mode, so
    the sharded path reuses it with ``offset=shard, stride=D``).

    The per-segment corpus slabs concatenate on the item axis, each
    padded to a whole number of ``block_n`` tiles with phantom dead
    rows; a static per-tile ``(q_off, q_len, row_base)`` int32 table —
    trace-time metadata, one row per grid step via a ``row_tiles(1, 3)``
    spec — windows each tile to its own segment's stacked query rows.
    Retrace keying: the tuple length S is part of the pytree structure,
    so callers bucket S (the frontend pads to power-of-two segment
    counts) exactly like Bq and K."""
    S = len(Q_parts)
    if S == 0:
        raise ValueError("dplr_corpus_score_multi needs >= 1 segment")
    S_a = len(a_parts)                  # tuple arity: trace-static
    if not (S_a == S and P_C.shape[0] == S and a_C.shape[0] == S
            and e.shape[0] == S):
        raise ValueError(
            f"segment-count mismatch: {S} Q_parts vs {S_a} "
            f"a_parts, e {e.shape}, P_C {P_C.shape}, a_C {a_C.shape}")
    if valid_parts is None:
        valid_parts = (None,) * S
    rho, k = Q_parts[0].shape[1:]
    Bq = P_C.shape[1]
    SB = S * Bq
    acc = jnp.dtype(acc_dtype)
    n_min = min(int(q.shape[0]) for q in Q_parts)
    if not 0 < topk <= n_min:
        raise ValueError(f"topk={topk} out of range for smallest segment "
                         f"n={n_min}")
    block_n = blocks.clamp_tile(block_n, max(int(q.shape[0])
                                             for q in Q_parts))

    q_cat, a_cat, m_cat, meta = [], [], [], []
    for s in range(S):
        q_s = Q_parts[s].astype(jnp.float32)
        a_s = a_parts[s].astype(jnp.float32)
        n_s = q_s.shape[0]
        m_s = (jnp.ones((n_s,), jnp.int32) if valid_parts[s] is None
               else jnp.asarray(valid_parts[s]).astype(jnp.int32))
        pad = blocks.pad_amount(n_s, block_n)
        if pad:
            q_s = jnp.pad(q_s, ((0, pad), (0, 0), (0, 0)))
            a_s = jnp.pad(a_s, (0, pad))
            m_s = jnp.pad(m_s, (0, pad))    # phantom rows are dead slots
        q_cat.append(q_s)
        a_cat.append(a_s)
        m_cat.append(m_s)
        for j in range((n_s + pad) // block_n):
            meta.append((s * Bq, Bq, j * block_n))
    Q_cat = jnp.concatenate(q_cat)
    a_cat = jnp.concatenate(a_cat)
    m_cat = jnp.concatenate(m_cat)
    meta = jnp.asarray(meta, jnp.int32)          # (n_tiles, 3), static
    grid = blocks.grid_1d(Q_cat.shape[0], block_n)

    e_q = jnp.repeat(e.astype(jnp.float32), Bq, axis=0)        # (SB, rho)
    pc = P_C.astype(jnp.float32).reshape(SB, rho, k)
    ac = a_C.astype(jnp.float32).reshape(SB)
    off = jnp.asarray(index_offset, jnp.int32).reshape(1, 1)

    in_specs = [
        blocks.row_tiles(block_n, rho, k),
        blocks.row_tiles(block_n, 1),
        blocks.row_tiles(block_n, 1),
        blocks.row_tiles(1, 3),
        blocks.broadcast(SB, rho),
        blocks.broadcast(SB, rho, k),
        blocks.broadcast(SB, 1),
        blocks.broadcast(1, 1),
    ]
    args = (Q_cat, a_cat[:, None], m_cat[:, None], meta, e_q, pc,
            ac[:, None], off)
    kernel = functools.partial(_kernel_multi_topk, topk=topk,
                               index_stride=index_stride, acc_dtype=acc)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            blocks.broadcast(SB, topk),
            blocks.broadcast(SB, topk),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((SB, topk), jnp.float32),
            jax.ShapeDtypeStruct((SB, topk), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
    return vals.reshape(S, Bq, topk), idx.reshape(S, Bq, topk)
