"""Pallas TPU flash attention (fwd): blocked causal/sliding-window GQA.

Classic FlashAttention-2 streaming-softmax structure adapted to TPU:
grid = (B, KV_heads, q_blocks); the kv loop is the innermost GRID dim
(Mosaic pipelines the k/v block DMAs), with running (max, sum, acc)
carried in VMEM scratch across kv steps.  Block shapes keep the MXU busy:
q block (block_q, hd) x k block (block_k, hd)^T is a (block_q, block_k)
MXU tile; block_q = block_k = 128 aligns both operands to the 128-lane
systolic array.

Causality and the sliding window are handled two ways:
  * block-level: kv blocks entirely outside [q_lo - W, q_hi] are skipped
    via @pl.when (no DMA waste is possible — the block is already resident
    — but the MXU work is skipped; FLOP savings show up on real hardware)
  * element-level: the boundary blocks apply the (q_pos >= k_pos) /
    window mask inside the block.

This is the serving-path kernel for the LM architectures; the pure-JAX
chunked attention in models/transformer/attention.py remains the
dry-run/compile path (Pallas cannot lower to the CPU backend), and the
tests assert the two agree in interpret mode.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocks

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, n_kv_blocks: int, group: int,
            window, softmax_scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * block_q
    k_lo = ki * block_k
    # skip kv blocks with no causal/window overlap with this q block
    in_causal = k_lo <= q_lo + block_q - 1
    in_window = True
    if window is not None:
        in_window = (k_lo + block_k - 1) > (q_lo - window)

    @pl.when(in_causal & in_window)
    def _compute():
        q = q_ref[0, 0, ...]                 # (block_q*G, hd) flattened q
        k = k_ref[0, 0, ...]                 # (block_k, hd)
        v = v_ref[0, 0, ...]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * softmax_scale
        q_pos = q_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_q * group, block_k), 0) // group
        k_pos = k_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_q * group, block_k), 1)
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0, 0, ...] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,            # (B, S, H, hd)
    k: jax.Array,            # (B, S, KV, hd)
    v: jax.Array,            # (B, S, KV, hd)
    *,
    window: int | None = None,
    block_q: int = blocks.ATTN_TILE,
    block_k: int = blocks.ATTN_TILE,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = 1.0 / np.sqrt(hd)

    # layout: fold the GQA group into the q-row dim so one kv-head's q rows
    # form a contiguous (block_q * G, hd) MXU operand.
    qg = q.reshape(B, S, KV, G, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(B, KV, S * G, hd)
    kg = k.transpose(0, 2, 1, 3)             # (B, KV, S, hd)
    vg = v.transpose(0, 2, 1, 3)

    n_q = S // block_q
    n_k = S // block_k
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, n_kv_blocks=n_k,
        group=G, window=window, softmax_scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_q, n_k),
        in_specs=[
            blocks.attn_tiles(block_q * G, hd, kv=False),
            blocks.attn_tiles(block_k, hd, kv=True),
            blocks.attn_tiles(block_k, hd, kv=True),
        ],
        out_specs=blocks.attn_tiles(block_q * G, hd, kv=False),
        out_shape=jax.ShapeDtypeStruct((B, KV, S * G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * G, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q * G, 1), jnp.float32),    # running sum
            pltpu.VMEM((block_q * G, hd), jnp.float32),   # output accum
        ],
        interpret=interpret,
    )(qg, kg, vg)
    out = out.reshape(B, KV, S, G, hd).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, S, H, hd)
