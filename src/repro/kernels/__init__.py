"""Pallas TPU kernels for the compute hot-spots of DPLR-FwFM serving.

Each kernel ships three files:
    <name>.py  - pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py     - jit'd public wrappers (block-size selection, interpret
                 fallback on CPU)
    ref.py     - pure-jnp oracles the tests sweep against

Kernels:
    dplr_score        - Algorithm 1 item scoring (the paper's hot op)
    dplr_corpus_score - corpus-precomputed batched scoring + fused top-K
                        (one HBM pass over (n, rho, k) instead of
                        (n, m_I, k) — the serving-engine hot op)
    fwfm_interaction  - full O(m^2 k) FwFM pairwise term (the baseline)
    embedding_bag     - scalar-prefetch gather + weighted bag reduce
    flash_attention   - blocked causal/windowed GQA attention (LM serving)
"""
