"""Pallas TPU kernel for DPLR-FwFM item scoring (Algorithm 1, steps 2-3).

Per candidate item with field embeddings V in R^{mI x k}:

    P      = P_C + U_I V                      (rho x k)
    score  = 0.5 * (s_C + sum_i d_i ||v_i||^2 + sum_r e_r ||P_r||^2)

The serving workload scores n ~ 1e3..1e6 candidates per query, so the
kernel tiles the ITEM axis into the MXU lane dimension: a block of
``block_n`` items is resident in VMEM as (block_n, mI*k); the projection
U_I V for the whole block is ONE (block_n, mI*k) x (mI*k -> rho*k)
contraction — realized by contracting over mI with k broadcast, i.e. an
einsum the Mosaic compiler maps onto the MXU with items in the sublane
dim.  Per-block working set:

    V block:  block_n * mI * k * 4B    (e.g. 1024 x 38 x 16 x 4 = 2.4 MB)
    U_I/e/d/P_C: < 32 KB (replicated per block, VMEM-resident)

so HBM traffic is exactly one pass over the candidate embeddings — the
roofline minimum for this op.  The context tensors (P_C, s_C) are the
cached per-query values; their cost is amortized over all items, which is
the paper's entire point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import blocks


def _kernel(v_ref, u_ref, e_ref, d_ref, pc_ref, sc_ref, out_ref):
    # v: (bn, mI, k); u: (rho, mI); e: (rho, 1); d: (mI, 1); pc: (rho, k)
    v = v_ref[...]
    u = u_ref[...]
    e = e_ref[...]
    d = d_ref[...]
    pc = pc_ref[...]
    sc = sc_ref[0, 0]
    # P = P_C + U_I @ V   -> (bn, rho, k); contraction over mI on the MXU
    p = pc[None, :, :] + jax.lax.dot_general(
        u, v,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2)
    term_e = jnp.einsum("nrk,r->n", p * p, e[:, 0])
    term_d = jnp.einsum("nmk,m->n", v * v, d[:, 0])
    out_ref[...] = 0.5 * (sc + term_d + term_e)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dplr_score_items(
    V_I: jax.Array,    # (n, mI, k) candidate field embeddings
    U_I: jax.Array,    # (rho, mI)
    e: jax.Array,      # (rho,)
    d_I: jax.Array,    # (mI,)   item part of the structural diagonal
    P_C: jax.Array,    # (rho, k) cached context projection
    s_C: jax.Array,    # ()       cached context d-term
    *,
    block_n: int = blocks.ITEM_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    n, mI, k = V_I.shape
    rho = U_I.shape[0]
    block_n = blocks.clamp_tile(block_n, n)
    pad = blocks.pad_amount(n, block_n)
    if pad:
        V_I = jnp.pad(V_I, ((0, pad), (0, 0), (0, 0)))
    n_pad = V_I.shape[0]

    grid = blocks.grid_1d(n_pad, block_n)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            blocks.row_tiles(block_n, mI, k),
            blocks.broadcast(rho, mI),
            blocks.broadcast(rho, 1),
            blocks.broadcast(mI, 1),
            blocks.broadcast(rho, k),
            blocks.broadcast(1, 1),
        ],
        out_specs=blocks.row_tiles(block_n),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(V_I, U_I, e[:, None], d_I[:, None], P_C, s_C[None, None])
    return out[:n]
