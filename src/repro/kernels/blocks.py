"""Shared Pallas tiling helpers: named tile sizes, padding, BlockSpecs.

Every kernel in this package expresses its grid and BlockSpec geometry
through these helpers instead of inline ``pl.BlockSpec``/magic-number
tile sizes — the invariant the kernel-contract linter rule
(``tools/analyze`` KRN-BLOCKSPEC / KRN-TILE) enforces.  Centralizing the
geometry buys three things: default tile sizes are NAMED (one place to
retune for a new TPU generation), index-map conventions are written once
(off-by-one in a hand-rolled ``lambda i: ...`` is the classic silent
Pallas bug), and the linter can verify "no bare tiling" purely
syntactically.

Conventions: all helpers target either a 1-D grid over tiles of axis 0
(``grid_1d`` + ``row_tiles``/``broadcast``/``col_tiles``), the
attention ``(B, KV, n_q, n_k)`` grid (``attn_tiles``), or the
scalar-prefetch gather grid (``prefetch_*``).  Tile-size defaults live
here as module constants.

Two tile-size services beyond the static defaults:

  * **clamp events** — ``clamp_tile`` no longer shrinks a tile silently:
    every clamp is recorded (trace-time Python side effect, like the
    runtime's ``trace_count``) and drainable via ``drain_clamp_events``,
    so the autotuner and benchmarks can report requested-vs-effective
    tile divergence instead of hiding it (the "no silent caps" rule).
  * **tuned-tile registry** — ``kernels/autotune.py`` registers the
    winning ``(block_n, acc_dtype)`` per parity-gated shape cell via
    ``register_tuned_tile``; ``corpus_tile`` is the lookup every call
    site that passes ``block_n=None`` resolves through (exact cell
    first, then the newest winner for the same ``(n, rho, k, dtype,
    backend)``, then ``CORPUS_TILE_N``).  Lookups happen at TRACE time
    inside the jitted callers, so tuning must run before warmup to take
    effect — a registry change never retraces an already-warm shape.
"""
from __future__ import annotations

import functools

from jax.experimental import pallas as pl

# Named default tile sizes (retune here, not at call sites).  Values are
# VMEM-budget choices for the f32 shapes documented in each kernel.
CORPUS_TILE_N = 2048    # dplr_corpus_score: item-axis tile of (n, rho, k)
ITEM_TILE_N = 1024      # dplr_score_items: item-axis tile of (n, mI, k)
PAIRWISE_TILE_B = 512   # fwfm_pairwise: example-axis tile of (B, m, k)
ATTN_TILE = 128         # flash_attention: q/k row tile (MXU lane width)

# Bounded log of tile clamps (requested > axis length).  Appended at
# trace time by clamp_tile; drained by the autotuner / benchmarks.
_CLAMP_EVENTS: list[dict] = []
_CLAMP_EVENTS_MAX = 256


def clamp_tile(tile: int, n: int) -> int:
    """Shrink a default tile to the axis length (tiny inputs trace a
    single-step grid instead of over-padding).  Never silent: each clamp
    is recorded for ``drain_clamp_events`` readers."""
    clamped = min(tile, n)
    if clamped != tile and len(_CLAMP_EVENTS) < _CLAMP_EVENTS_MAX:
        _CLAMP_EVENTS.append(
            {"requested": int(tile), "effective": int(clamped),
             "n": int(n)})
    return clamped


def drain_clamp_events() -> list[dict]:
    """Return and clear the recorded clamp events (bounded at
    ``_CLAMP_EVENTS_MAX``): ``{"requested", "effective", "n"}`` dicts in
    occurrence order."""
    out = list(_CLAMP_EVENTS)
    _CLAMP_EVENTS.clear()
    return out


# -- tuned-tile registry (written by kernels/autotune.py) -------------------

# exact cell (n, rho, k, Bq, K, dtype, backend) -> (block_n, acc_dtype)
_TUNED_TILES: dict[tuple, tuple[int, str]] = {}
# newest winner per shape family (n, rho, k, dtype, backend), used when a
# call's (Bq, K) cell was never tuned directly
_TUNED_FAMILY: dict[tuple, tuple[int, str]] = {}


def tile_cell(n: int, rho: int, k: int, Bq: int, K: int | None,
              dtype: str, backend: str) -> tuple:
    """The registry key of one autotuned shape cell."""
    return (int(n), int(rho), int(k), int(Bq),
            None if K is None else int(K), str(dtype), str(backend))


def register_tuned_tile(cell: tuple, block_n: int,
                        acc_dtype: str = "float32") -> None:
    """Record a parity-gated autotune winner for ``cell`` (a
    ``tile_cell`` tuple).  Only ``kernels/autotune.py`` should call this,
    and only AFTER the candidate passed its oracle parity gate — the
    KRN-TUNE analyzer rule enforces that pairing statically."""
    cell = tuple(cell)
    winner = (int(block_n), str(acc_dtype))
    _TUNED_TILES[cell] = winner
    _TUNED_FAMILY[cell[:3] + cell[5:]] = winner


def corpus_tile(n: int, rho: int, k: int, Bq: int, K: int | None,
                dtype: str, backend: str) -> tuple[int, str]:
    """Resolve the ``(block_n, acc_dtype)`` a ``block_n=None`` corpus-
    scorer call should use: the exact tuned cell if registered, else the
    newest winner of the same ``(n, rho, k, dtype, backend)`` family,
    else ``(CORPUS_TILE_N, 'float32')`` — so untuned processes behave
    exactly as before."""
    cell = tile_cell(n, rho, k, Bq, K, dtype, backend)
    hit = _TUNED_TILES.get(cell)
    if hit is None:
        hit = _TUNED_FAMILY.get(cell[:3] + cell[5:])
    return hit if hit is not None else (CORPUS_TILE_N, "float32")


def clear_tuned_tiles() -> None:
    """Drop every registered tuned tile (tests / benchmark hygiene)."""
    _TUNED_TILES.clear()
    _TUNED_FAMILY.clear()


def pad_amount(n: int, tile: int) -> int:
    """Rows of phantom padding that make ``n`` a whole number of tiles."""
    return (-n) % tile


def grid_1d(n_padded: int, tile: int) -> tuple[int]:
    """The 1-D grid over axis-0 tiles; ``n_padded`` must already be a
    tile multiple (``pad_amount`` says by how much to pad)."""
    if n_padded % tile:
        raise ValueError(f"n_padded={n_padded} not a multiple of "
                         f"tile={tile}")
    return (n_padded // tile,)


def row_tiles(tile: int, *rest: int) -> pl.BlockSpec:
    """``(tile, *rest)`` block, axis 0 tiled by the 1-D grid step, every
    trailing axis whole: grid step ``i`` sees rows ``[i*tile, (i+1)*tile)``."""
    trailing = (0,) * len(rest)
    return pl.BlockSpec((tile, *rest), lambda i: (i, *trailing))


def col_tiles(lead: int, tile: int) -> pl.BlockSpec:
    """``(lead, tile)`` block, axis 1 tiled by the 1-D grid step, axis 0
    whole — the output layout of a scorer that revisits all queries per
    item tile."""
    return pl.BlockSpec((lead, tile), lambda i: (0, i))


def broadcast(*shape: int) -> pl.BlockSpec:
    """A whole-array block with a constant index map: the operand stays
    VMEM-resident across every 1-D grid step (replicated operands, and
    running top-K output blocks carried across steps)."""
    zeros = (0,) * len(shape)
    return pl.BlockSpec(tuple(shape), lambda i: zeros)


def attn_tiles(block_rows: int, head_dim: int, *, kv: bool) -> pl.BlockSpec:
    """``(1, 1, block_rows, head_dim)`` block of a ``(B, KV, S, hd)``
    operand on the attention grid ``(B, KV, n_q, n_k)``: one
    (batch, kv-head) pair per step, rows tiled by the kv grid axis when
    ``kv`` else by the q grid axis."""
    if kv:
        return pl.BlockSpec((1, 1, block_rows, head_dim),
                            lambda b, h, qi, ki: (b, h, ki, 0))
    return pl.BlockSpec((1, 1, block_rows, head_dim),
                        lambda b, h, qi, ki: (b, h, qi, 0))


def prefetch_batch(*rest: int) -> pl.BlockSpec:
    """``(1, *rest)`` block of a batch-major operand on the scalar-
    prefetch gather grid ``(B,)``: step ``i`` sees example ``i`` whole
    (the prefetch ref is part of the index-map signature but unused)."""
    trailing = (0,) * len(rest)
    return pl.BlockSpec((1, *rest), lambda i, ids_ref: (i, *trailing))


def prefetch_rows(n_slots: int, row_width: int) -> list[pl.BlockSpec]:
    """One ``(1, row_width)`` table-row view per slot on the scalar-
    prefetch grid: view ``s`` of grid step ``i`` DMAs table row
    ``ids[i, s]`` into VMEM — the data-dependent gather, driven by the
    prefetched ids."""
    return [
        pl.BlockSpec((1, row_width), functools.partial(
            lambda i, ids_ref, s=0: (ids_ref[i, s], 0), s=s))
        for s in range(n_slots)
    ]
