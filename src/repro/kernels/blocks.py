"""Shared Pallas tiling helpers: named tile sizes, padding, BlockSpecs.

Every kernel in this package expresses its grid and BlockSpec geometry
through these helpers instead of inline ``pl.BlockSpec``/magic-number
tile sizes — the invariant the kernel-contract linter rule
(``tools/analyze`` KRN-BLOCKSPEC / KRN-TILE) enforces.  Centralizing the
geometry buys three things: default tile sizes are NAMED (one place to
retune for a new TPU generation), index-map conventions are written once
(off-by-one in a hand-rolled ``lambda i: ...`` is the classic silent
Pallas bug), and the linter can verify "no bare tiling" purely
syntactically.

Conventions: all helpers target either a 1-D grid over tiles of axis 0
(``grid_1d`` + ``row_tiles``/``broadcast``/``col_tiles``), the
attention ``(B, KV, n_q, n_k)`` grid (``attn_tiles``), or the
scalar-prefetch gather grid (``prefetch_*``).  Tile-size defaults live
here as module constants.
"""
from __future__ import annotations

import functools

from jax.experimental import pallas as pl

# Named default tile sizes (retune here, not at call sites).  Values are
# VMEM-budget choices for the f32 shapes documented in each kernel.
CORPUS_TILE_N = 2048    # dplr_corpus_score: item-axis tile of (n, rho, k)
ITEM_TILE_N = 1024      # dplr_score_items: item-axis tile of (n, mI, k)
PAIRWISE_TILE_B = 512   # fwfm_pairwise: example-axis tile of (B, m, k)
ATTN_TILE = 128         # flash_attention: q/k row tile (MXU lane width)


def clamp_tile(tile: int, n: int) -> int:
    """Shrink a default tile to the axis length (tiny inputs trace a
    single-step grid instead of over-padding)."""
    return min(tile, n)


def pad_amount(n: int, tile: int) -> int:
    """Rows of phantom padding that make ``n`` a whole number of tiles."""
    return (-n) % tile


def grid_1d(n_padded: int, tile: int) -> tuple[int]:
    """The 1-D grid over axis-0 tiles; ``n_padded`` must already be a
    tile multiple (``pad_amount`` says by how much to pad)."""
    if n_padded % tile:
        raise ValueError(f"n_padded={n_padded} not a multiple of "
                         f"tile={tile}")
    return (n_padded // tile,)


def row_tiles(tile: int, *rest: int) -> pl.BlockSpec:
    """``(tile, *rest)`` block, axis 0 tiled by the 1-D grid step, every
    trailing axis whole: grid step ``i`` sees rows ``[i*tile, (i+1)*tile)``."""
    trailing = (0,) * len(rest)
    return pl.BlockSpec((tile, *rest), lambda i: (i, *trailing))


def col_tiles(lead: int, tile: int) -> pl.BlockSpec:
    """``(lead, tile)`` block, axis 1 tiled by the 1-D grid step, axis 0
    whole — the output layout of a scorer that revisits all queries per
    item tile."""
    return pl.BlockSpec((lead, tile), lambda i: (0, i))


def broadcast(*shape: int) -> pl.BlockSpec:
    """A whole-array block with a constant index map: the operand stays
    VMEM-resident across every 1-D grid step (replicated operands, and
    running top-K output blocks carried across steps)."""
    zeros = (0,) * len(shape)
    return pl.BlockSpec(tuple(shape), lambda i: zeros)


def attn_tiles(block_rows: int, head_dim: int, *, kv: bool) -> pl.BlockSpec:
    """``(1, 1, block_rows, head_dim)`` block of a ``(B, KV, S, hd)``
    operand on the attention grid ``(B, KV, n_q, n_k)``: one
    (batch, kv-head) pair per step, rows tiled by the kv grid axis when
    ``kv`` else by the q grid axis."""
    if kv:
        return pl.BlockSpec((1, 1, block_rows, head_dim),
                            lambda b, h, qi, ki: (b, h, ki, 0))
    return pl.BlockSpec((1, 1, block_rows, head_dim),
                        lambda b, h, qi, ki: (b, h, qi, 0))


def prefetch_batch(*rest: int) -> pl.BlockSpec:
    """``(1, *rest)`` block of a batch-major operand on the scalar-
    prefetch gather grid ``(B,)``: step ``i`` sees example ``i`` whole
    (the prefetch ref is part of the index-map signature but unused)."""
    trailing = (0,) * len(rest)
    return pl.BlockSpec((1, *rest), lambda i, ids_ref: (i, *trailing))


def prefetch_rows(n_slots: int, row_width: int) -> list[pl.BlockSpec]:
    """One ``(1, row_width)`` table-row view per slot on the scalar-
    prefetch grid: view ``s`` of grid step ``i`` DMAs table row
    ``ids[i, s]`` into VMEM — the data-dependent gather, driven by the
    prefetched ids."""
    return [
        pl.BlockSpec((1, row_width), functools.partial(
            lambda i, ids_ref, s=0: (ids_ref[i, s], 0), s=s))
        for s in range(n_slots)
    ]
