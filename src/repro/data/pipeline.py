"""Host-sharded, prefetching data pipeline.

At 1000+ node scale each host feeds only its slice of the global batch:
``ShardedPipeline`` derives a per-(host, step) seed so (a) every host draws
disjoint data deterministically with NO host-to-host coordination, and
(b) restarts resume mid-epoch byte-identically (the seed is a pure function
of the step — data state never needs checkpointing).

Straggler mitigation: producer threads fill a bounded queue; if a batch
misses ``straggler_timeout_s`` the consumer re-serves the previous batch
instead of stalling the step (a documented accuracy/throughput trade used
by large sync-SGD systems), and the event is counted for monitoring.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable


class ShardedPipeline:
    def __init__(
        self,
        make_batch: Callable[[int], dict],   # step -> host-local batch
        prefetch: int = 2,
        straggler_timeout_s: float | None = None,
    ):
        self.make_batch = make_batch
        self.prefetch = prefetch
        self.timeout = straggler_timeout_s
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._next_step = 0
        self._last_batch = None
        self.straggler_events = 0

    def start(self, from_step: int = 0):
        self._next_step = from_step
        self._stop.clear()

        def produce():
            step = from_step
            while not self._stop.is_set():
                batch = self.make_batch(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=produce, daemon=True)
        self._thread.start()
        return self

    def get(self) -> tuple[int, dict]:
        if self.timeout is None:
            return self._q.get()
        try:
            step, batch = self._q.get(timeout=self.timeout)
            self._last_batch = batch
            return step, batch
        except queue.Empty:
            # straggler: reuse the previous batch rather than stall the sync
            # step; counted so monitoring can alert on data-path slowness.
            self.straggler_events += 1
            if self._last_batch is None:
                return self._q.get()   # nothing cached yet: block
            return -1, self._last_batch

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)


def host_shard_seed(global_seed: int, host_id: int, step: int) -> int:
    """Pure-function seed: disjoint per host, replayable per step."""
    return hash((global_seed, host_id, step)) % (2**63)
