from repro.data.synthetic_ctr import SyntheticCTR  # noqa: F401
from repro.data.pipeline import ShardedPipeline  # noqa: F401
