"""Synthetic CTR data with PLANTED low-rank field-interaction structure.

The paper's public datasets (Criteo/Avazu/MovieLens) are not available
offline, so benchmarks draw from a generator whose ground truth is itself an
FwFM with field matrix  R* = U*^T diag(e*) U* + diag(d*)  of rank r* plus
optional dense noise:

    ids_f  ~ Zipf(alpha) per field           (realistic head-heavy traffic)
    $phi(x) = b0 + <b, x> + sum_{i<j} <v_i, v_j> R*_ij$
    label  ~ Bernoulli(sigmoid(phi / temperature))

This makes the paper's claims *testable* offline: a DPLR model with rank >=
r* can match the teacher; magnitude pruning at the equivalent parameter
count cannot represent R* and loses accuracy (Table 1's ordering).  The
noise_rank knob interpolates toward a full-rank teacher where both
approximations degrade.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fields import FeatureLayout


@dataclasses.dataclass
class SyntheticCTR:
    layout: FeatureLayout
    embed_dim: int = 8
    teacher_rank: int = 2
    noise_scale: float = 0.0      # dense full-rank perturbation of R*
    zipf_alpha: float = 1.3
    temperature: float = 1.0
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        m = self.layout.n_fields
        k = self.embed_dim
        # teacher DPLR field matrix with BLOCK-HETEROGENEOUS factors — the
        # paper's motivating observation is that real field matrices show
        # block structure from field groups.  A homogeneous rank-1 teacher
        # (all entries ~1) would make magnitude pruning a mere RESCALING of
        # the pairwise term, which AUC cannot see; mixed-sign, mixed-scale
        # factors make the pruned-away entries carry ranking signal.
        U = (rng.choice([-1.2, -0.4, 0.4, 1.2], (self.teacher_rank, m))
             * (1.0 + 0.3 * rng.standard_normal((self.teacher_rank, m))))
        e = rng.choice([-1.0, 1.0], self.teacher_rank) * \
            (1.0 + 0.5 * rng.random(self.teacher_rank))
        low = (U.T * e) @ U / np.sqrt(m)
        R = low + self.noise_scale * rng.standard_normal((m, m)) / m
        R = 0.5 * (R + R.T)
        np.fill_diagonal(R, 0.0)
        self.R_true = R.astype(np.float32)
        self.emb_true = (rng.standard_normal(
            (self.layout.total_vocab, k)) / np.sqrt(k)).astype(np.float32)
        self.lin_true = (rng.standard_normal(self.layout.total_vocab)
                         * 0.05).astype(np.float32)
        self.b0 = float(rng.standard_normal() * 0.1)
        # per-field Zipf id distribution (resampled into [0, vocab))
        self._rng = rng

    def _sample_ids(self, rng, batch: int) -> np.ndarray:
        cols = []
        for f in self.layout.fields:
            for _ in range(f.multiplicity):
                raw = rng.zipf(self.zipf_alpha, batch)
                cols.append((raw - 1) % f.vocab_size)
        return np.stack(cols, axis=1).astype(np.int32)

    def logits(self, ids: np.ndarray, weights: np.ndarray) -> np.ndarray:
        """Teacher score for encoded rows (numpy reference)."""
        offs = self.layout.slot_offsets
        rows = ids + offs
        emb = self.emb_true[rows] * weights[..., None]      # (B, slots, k)
        m = self.layout.n_fields
        V = np.zeros((ids.shape[0], m, self.embed_dim), np.float32)
        np.add.at(V, (slice(None), self.layout.slot_to_field), emb)
        G = np.einsum("bik,bjk->bij", V, V)
        pair = 0.5 * np.einsum("bij,ij->b", G, self.R_true)
        lin = (self.lin_true[rows] * weights).sum(1)
        return self.b0 + lin + pair

    def batch(self, batch_size: int, seed: int) -> dict:
        """Deterministic batch keyed by seed (host-shardable, replayable)."""
        rng = np.random.default_rng((self.seed, seed))
        ids = self._sample_ids(rng, batch_size)
        weights = np.ones_like(ids, np.float32)
        z = self.logits(ids, weights) / self.temperature
        p = 1.0 / (1.0 + np.exp(-z))
        labels = (rng.random(batch_size) < p).astype(np.float32)
        return {"ids": ids, "weights": weights, "label": labels}

    def context_query(self, seed: int) -> dict:
        """One query context, no candidates — the corpus-engine serving
        workload, where the item side is the engine's static corpus."""
        rng = np.random.default_rng((self.seed, 7, seed))
        ctx_slots = self.layout.slots_of("context")
        ctx_ids = self._sample_ids(rng, 1)[:, ctx_slots]
        return {
            "context_ids": ctx_ids,
            "context_weights": np.ones_like(ctx_ids, np.float32),
        }

    def ranking_query(self, n_items: int, seed: int) -> dict:
        """One context + n candidate items (the serving workload)."""
        rng = np.random.default_rng((self.seed, 7, seed))
        ctx_slots = self.layout.slots_of("context")
        item_slots = self.layout.slots_of("item")
        ids = self._sample_ids(rng, n_items)
        ctx_ids = ids[:1, ctx_slots]
        item_ids = ids[None, :, item_slots]
        return {
            "context_ids": ctx_ids,
            "context_weights": np.ones_like(ctx_ids, np.float32),
            "item_ids": item_ids,
            "item_weights": np.ones_like(item_ids, np.float32),
        }
