"""Model-parallel embedding lookup over a row-sharded arena table.

The arena is block-sharded over the ``model`` mesh axis with
``PartitionSpec("model", None)``: shard ``s`` owns the contiguous row range
``[s * rows_per_shard, (s+1) * rows_per_shard)``.  Inside ``shard_map`` each
shard gathers the rows it owns (out-of-shard rows are masked to zero) and the
partial field-embedding bags are summed with ``psum`` over the model axis.

Collective cost per lookup: one all-reduce of the *output* bags
(batch_per_dp x n_fields x k floats), NOT of the table — the table never
moves.  This is the classic sharded-embedding pattern (Megatron's
VocabParallelEmbedding), built here from JAX primitives because JAX has no
native equivalent.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.fields import FeatureLayout
from repro.sharding import shard_map


def _local_masked_bag(
    table_shard: jax.Array,   # (rows_per_shard, k) local block
    arena_ids: jax.Array,     # (..., n_slots) global rows
    weights: jax.Array,       # (..., n_slots)
    segment_ids: np.ndarray,
    n_bags: int,
    axis_name: str,
) -> jax.Array:
    rows_per_shard = table_shard.shape[0]
    shard = jax.lax.axis_index(axis_name)
    owner = arena_ids // rows_per_shard
    local = arena_ids - owner * rows_per_shard
    mine = (owner == shard)
    # clip so the gather is always in-bounds; masked rows contribute 0.
    local = jnp.where(mine, local, 0)
    flat = jnp.take(table_shard, local, axis=0)
    w = jnp.where(mine, weights, 0.0).astype(flat.dtype)
    weighted = flat * w[..., None]
    out = jnp.zeros((*arena_ids.shape[:-1], n_bags, table_shard.shape[-1]),
                    dtype=flat.dtype)
    out = out.at[..., segment_ids, :].add(weighted)
    return jax.lax.psum(out, axis_name)


def _local_masked_take(
    table_shard: jax.Array,   # (rows_per_shard, k)
    ids: jax.Array,           # (...,) global rows
    axis_name: str,
) -> jax.Array:
    rows_per_shard = table_shard.shape[0]
    shard = jax.lax.axis_index(axis_name)
    owner = ids // rows_per_shard
    local = ids - owner * rows_per_shard
    mine = (owner == shard)
    rows = jnp.take(table_shard, jnp.where(mine, local, 0), axis=0)
    rows = jnp.where(mine[..., None], rows, 0)
    return jax.lax.psum(rows, axis_name)


def make_sharded_take(mesh: jax.sharding.Mesh, spec_by_rank: dict[int, P],
                      model_axis: str = "model"):
    """Build a ``take_fn(table, ids)`` for model-parallel arenas.

    ``spec_by_rank`` maps ids.ndim -> PartitionSpec of the ids array (how the
    batch dims are sharded); the table must be P(model_axis, None)-sharded
    and row-count divisible by the model axis (see ``padded_rows``).
    Each device gathers the rows it owns; a psum over the model axis
    assembles full rows.  The table itself never moves.
    """

    def take_fn(table, ids):
        ispec = spec_by_rank[ids.ndim]
        out_spec = P(*(tuple(ispec) + (None,)))
        fn = partial(_local_masked_take, axis_name=model_axis)
        return shard_map(
            fn, mesh=mesh,
            in_specs=(P(model_axis, None), ispec),
            out_specs=out_spec,
        )(table, ids)

    return take_fn


def sharded_lookup_field_embeddings(
    table: jax.Array,
    layout: FeatureLayout,
    ids: jax.Array,
    weights: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    model_axis: str = "model",
    data_axes: tuple[str, ...] = ("data",),
) -> jax.Array:
    """shard_map'd field-embedding lookup.

    ``table`` must be sharded ``P(model_axis, None)``; the batch dims of
    ``ids``/``weights`` sharded over ``data_axes``; output follows the batch.
    """
    arena_ids = ids + jnp.asarray(layout.slot_offsets)
    batch_spec = P(data_axes)
    fn = partial(
        _local_masked_bag,
        segment_ids=layout.slot_to_field,
        n_bags=layout.n_fields,
        axis_name=model_axis,
    )
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(model_axis, None), batch_spec, batch_spec),
        out_specs=batch_spec,
    )(table, arena_ids, weights)
