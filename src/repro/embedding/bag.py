"""EmbeddingBag substrate.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse gather, so the
production embedding path is built from first principles:

  * gather  : ``jnp.take`` over a single arena table (all fields share one
              table with per-field row offsets — the standard production
              layout, one allocation, one gather)
  * reduce  : scatter-add of per-slot embeddings into per-field bags via
              ``x.at[:, slot_to_field].add(...)`` (multi-hot fields average
              their value embeddings per the paper, Section 3.2)

This module is the single-device reference path; ``repro.embedding.sharded``
implements the model-parallel (row-sharded) version used on the production
mesh, and ``repro.kernels.embedding_bag`` is the Pallas TPU kernel for the
gather+reduce hot loop.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.fields import FeatureLayout


def init_embedding_table(
    rng: jax.Array,
    n_rows: int,
    dim: int,
    *,
    scale: float | None = None,
    dtype=jnp.float32,
) -> jax.Array:
    """Initialize an embedding arena. Default scale 1/sqrt(dim) (FM-standard)."""
    if scale is None:
        scale = 1.0 / np.sqrt(dim)
    return (jax.random.normal(rng, (n_rows, dim)) * scale).astype(dtype)


def embedding_bag(
    table: jax.Array,          # (n_rows, k)
    ids: jax.Array,            # (..., n_slots) int32, arena-global rows
    weights: jax.Array,        # (..., n_slots) f32
    segment_ids: np.ndarray,   # (n_slots,) static slot -> bag mapping
    n_bags: int,
    take_fn=None,              # pluggable gather (model-parallel lookup)
) -> jax.Array:
    """Weighted gather-reduce: out[..., b, :] = sum_{s: seg[s]=b} w_s * table[id_s].

    The torch equivalent is ``nn.EmbeddingBag(mode='sum')`` with per-sample
    weights, generalized to many bags per example.  ``take_fn(table, ids)``
    overrides the row gather — the distributed step passes the shard_map'd
    masked-take+psum lookup so sharded arenas never move.
    """
    take = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    flat = take(table, ids)                               # (..., n_slots, k)
    weighted = flat * weights[..., None].astype(flat.dtype)
    out_shape = (*ids.shape[:-1], n_bags, table.shape[-1])
    out = jnp.zeros(out_shape, dtype=flat.dtype)
    # scatter-add over the slot axis into bags; segment_ids is static.
    return out.at[..., segment_ids, :].add(weighted)


def lookup_field_embeddings(
    table: jax.Array,
    layout: FeatureLayout,
    ids: jax.Array,       # (batch..., n_slots) *local* per-field ids
    weights: jax.Array,   # (batch..., n_slots)
    take_fn=None,
) -> jax.Array:
    """(batch..., n_fields, k) field embedding matrix V (rows of Eq. 4)."""
    arena_ids = ids + jnp.asarray(layout.slot_offsets)
    return embedding_bag(
        table, arena_ids, weights, layout.slot_to_field, layout.n_fields,
        take_fn=take_fn,
    )


def item_arena_ids(layout: FeatureLayout, ids: jax.Array) -> jax.Array:
    """Arena-global ids for item-side *local* slot ids.

    The arena stores context-field rows first, then item-field rows, so an
    item-side lookup shifts local ids by the total context vocab.  Shared by
    ``fwfm.rank_items``, the ranking-server example, and the corpus-cache
    builder (one definition of the offset math, not three copies).
    """
    return ids + layout.subset("context").total_vocab


def lookup_item_embeddings(
    table: jax.Array,
    layout: FeatureLayout,    # the FULL layout (context + item fields)
    ids: jax.Array,           # (..., n_item_slots) local item-side ids
    weights: jax.Array,       # (..., n_item_slots)
    take_fn=None,
) -> jax.Array:
    """(..., m_item, k) item-field embedding matrix V_I from local item ids."""
    item_layout = layout.subset("item")
    arena = item_arena_ids(layout, ids) + jnp.asarray(item_layout.slot_offsets)
    return embedding_bag(
        table, arena, weights, item_layout.slot_to_field,
        item_layout.n_fields, take_fn=take_fn,
    )


def lookup_linear_terms(
    table: jax.Array,     # (n_rows, 1) first-order weights
    layout: FeatureLayout,
    ids: jax.Array,
    weights: jax.Array,
    take_fn=None,
) -> jax.Array:
    """(batch...,) first-order term <b, x> of the FM/FwFM model."""
    tab = table.reshape(-1, 1)
    take = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    arena_ids = ids + jnp.asarray(layout.slot_offsets)
    vals = take(tab, arena_ids)[..., 0] * weights.astype(tab.dtype)
    return vals.sum(axis=-1)


def padded_rows(n_rows: int, multiple: int = 2048) -> int:
    """Arena rows padded so row-sharding divides any mesh axis we use."""
    return ((n_rows + multiple - 1) // multiple) * multiple


def spread_ids(ids: jax.Array, vocab_sizes: jax.Array, prime: int = 2654435761) -> jax.Array:
    """Load-balancing bijection id -> (id * prime) % vocab (prime > any vocab).

    Block-sharded tables put popular (low) ids on shard 0; Zipfian traffic
    then hot-spots that shard.  Multiplying by a fixed prime coprime to the
    vocab size permutes rows, spreading hot ids across shards.  Bijective
    iff gcd(prime, vocab) == 1, guaranteed when vocab < prime (prime is
    Knuth's 2^32 golden-ratio constant, larger than any per-field vocab).
    """
    return ((ids.astype(jnp.int64) * prime) % vocab_sizes.astype(jnp.int64)).astype(
        jnp.int32
    )
