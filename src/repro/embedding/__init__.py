from repro.embedding.bag import (  # noqa: F401
    init_embedding_table,
    embedding_bag,
    lookup_field_embeddings,
)
from repro.embedding.sharded import sharded_lookup_field_embeddings  # noqa: F401
